"""Columnar per-request tracing — the flight recorder's span store.

``TraceRecorder`` keeps one preallocated NumPy column per span field
and records a whole simulation window in a handful of vectorized array
copies (the PR-1 columnar idiom): no per-request Python objects are
created on the hot path, which is what keeps the measured tracing
overhead inside the CI gate (``benchmarks/tracing_overhead.py``).

Each request contributes one row decomposed into spans:

* ``queue_s`` — arrival → prefill start (includes preempt-resume gaps)
* ``prefill_s`` — GPU compute for the uncached suffix
* ``kv_load_s`` — SSD/DRAM KV fetch for the matched prefix
* ``decode_s`` — output_tokens × TPOT
* ``ttft_s`` / ``tpot_s`` — the reported latency metrics
* ``hit_kind``/``hit_tier``/``matched_tokens`` — what the cache did
* ``energy_j`` / ``carbon_g`` — attributed per-request energy and
  operational gCO₂e (window energy split evenly, priced at the window
  CI — the same attribution the ILP uses)

Rare control-plane happenings (plan transitions, replica failures,
preempt-resume, WAN KV migration) land in a small side event table.

Export: ``write_jsonl`` (one JSON object per row — requests then
events) and ``write_chrome`` (Chrome ``trace_event`` JSON: open it in
``chrome://tracing`` / Perfetto; pid = region, tid = replica).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["TraceRecorder", "SPAN_FIELDS", "HIT_KIND_CODES",
           "HIT_KIND_NAMES"]

# int8 codes for the recorded HitKind (UNKNOWN covers paths that cannot
# reconstruct the account result, e.g. the least_loaded router)
HIT_KIND_CODES = {"hit": 0, "partial": 1, "miss": 2, "too_large": 3,
                  "rejected": 4, "unknown": -1}
HIT_KIND_NAMES = {v: k for k, v in HIT_KIND_CODES.items()}

# (name, dtype) of every request-row column, in export order
SPAN_FIELDS = (
    ("rid", np.int64),
    ("arrival_s", np.float64),
    ("region", np.int16),          # interned label index
    ("replica", np.int32),
    ("tier", np.int16),            # interned label index
    ("tenant", np.int32),          # interned label index
    ("hit_kind", np.int8),
    ("hit_tier", np.int8),         # -1 flat/unknown, 0 hot, 1 cold
    ("matched_tokens", np.int32),
    ("prompt_tokens", np.int32),
    ("output_tokens", np.int32),
    ("queue_s", np.float64),
    ("prefill_s", np.float64),
    ("kv_load_s", np.float64),
    ("decode_s", np.float64),
    ("ttft_s", np.float64),
    ("tpot_s", np.float64),
    ("energy_j", np.float64),
    ("carbon_g", np.float64),
)


class _Interner:
    """Label string <-> small int, stable in first-seen order."""

    def __init__(self):
        self.index: Dict[str, int] = {}
        self.labels: List[str] = []

    def __call__(self, label: str) -> int:
        i = self.index.get(label)
        if i is None:
            i = self.index[label] = len(self.labels)
            self.labels.append(label)
        return i

    def many(self, labels: Sequence[str]) -> np.ndarray:
        return np.fromiter((self(x) for x in labels), np.int32,
                           count=len(labels))


class TraceRecorder:
    """Opt-in columnar span recorder.

    Attach to engines via ``GreenCacheController(trace=...)`` or
    ``engine.recorder = TraceRecorder()``; detached (``None``) engines
    skip every recording branch, which is the bit-identity contract.
    """

    def __init__(self, capacity: int = 8192):
        self.capacity = max(int(capacity), 16)
        self.n = 0
        self._cols = {name: np.zeros(self.capacity, dtype=dt)
                      for name, dt in SPAN_FIELDS}
        self.regions = _Interner()
        self.tiers = _Interner()
        self.tenants = _Interner()
        # rare control-plane events: list of small dicts (transitions,
        # failures, preemptions, WAN migrations — O(events), not O(reqs))
        self.events: List[Dict] = []

    # ------------------------------------------------------------------ #
    def _grow(self, need: int):
        cap = self.capacity
        while cap < self.n + need:
            cap *= 2
        if cap != self.capacity:
            for name, col in self._cols.items():
                ext = np.zeros(cap, dtype=col.dtype)
                ext[:self.n] = col[:self.n]
                self._cols[name] = ext
            self.capacity = cap

    def record_window(self, *, rids, arrival, ttft, tpot,
                      prefill_s, kv_load_s, queue_s,
                      prompt_tokens, output_tokens, matched_tokens,
                      hit_kind, hit_tier=None, replica=None,
                      energy_j_per_req: float = 0.0,
                      ci_g_per_kwh: float = 0.0,
                      region: str = "",
                      tiers: Optional[Sequence[str]] = None,
                      tenants: Optional[Sequence[str]] = None):
        """Record one simulated window's request stream from the
        engine's existing arrays — a handful of vectorized column
        copies, no per-request Python objects."""
        k = len(arrival)
        if k == 0:
            return
        self._grow(k)
        s = slice(self.n, self.n + k)
        c = self._cols
        c["rid"][s] = rids
        c["arrival_s"][s] = arrival
        c["region"][s] = self.regions(region)
        c["replica"][s] = 0 if replica is None else replica
        c["tier"][s] = 0 if tiers is None else self.tiers.many(tiers)
        if tiers is None:
            self.tiers("")          # keep index 0 = the untier label
        c["tenant"][s] = 0 if tenants is None \
            else self.tenants.many(tenants)
        if tenants is None:
            self.tenants("")
        c["hit_kind"][s] = hit_kind
        c["hit_tier"][s] = -1 if hit_tier is None else hit_tier
        c["matched_tokens"][s] = matched_tokens
        c["prompt_tokens"][s] = prompt_tokens
        c["output_tokens"][s] = output_tokens
        c["queue_s"][s] = queue_s
        c["prefill_s"][s] = prefill_s
        c["kv_load_s"][s] = kv_load_s
        c["decode_s"][s] = np.asarray(output_tokens) * np.asarray(tpot)
        c["ttft_s"][s] = ttft
        c["tpot_s"][s] = tpot
        c["energy_j"][s] = energy_j_per_req
        c["carbon_g"][s] = (energy_j_per_req / 3.6e6) * ci_g_per_kwh
        self.n += k

    def record_event(self, kind: str, ts: float, *, region: str = "",
                     **attrs):
        """Control-plane event (transition, failure, preempt, WAN
        migration) — rare, so a plain dict row is fine."""
        ev = {"kind": str(kind), "ts": float(ts), "region": str(region)}
        ev.update(attrs)
        self.events.append(ev)

    # ------------------------------------------------------------------ #
    def column(self, name: str) -> np.ndarray:
        """Live view of one column's recorded prefix."""
        return self._cols[name][:self.n]

    def percentile(self, name: str, q) -> float:
        col = self.column(name)
        if not len(col):
            return 0.0
        return float(np.percentile(col, q))

    def percentiles(self, name: str,
                    qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
        return {f"p{int(q)}": self.percentile(name, q) for q in qs}

    # ------------------------------------------------------------------ #
    def rows(self):
        """Iterate request rows as plain dicts (export path only)."""
        c = self._cols
        for i in range(self.n):
            row = {}
            for name, _ in SPAN_FIELDS:
                v = c[name][i]
                row[name] = v.item()
            row["region"] = self.regions.labels[int(c["region"][i])] \
                if self.regions.labels else ""
            row["tier"] = self.tiers.labels[int(c["tier"][i])] \
                if self.tiers.labels else ""
            row["tenant"] = self.tenants.labels[int(c["tenant"][i])] \
                if self.tenants.labels else ""
            row["hit_kind"] = HIT_KIND_NAMES.get(int(c["hit_kind"][i]),
                                                 "unknown")
            yield row

    def write_jsonl(self, path: str):
        """One JSON object per line: request span rows (``type:
        "request"``), then control-plane events (``type: "event"``)."""
        with open(path, "w") as f:
            for row in self.rows():
                row["type"] = "request"
                f.write(json.dumps(row) + "\n")
            for ev in self.events:
                out = dict(ev)
                out["type"] = "event"
                f.write(json.dumps(out) + "\n")

    def write_chrome(self, path: str):
        """Chrome ``trace_event`` export: complete ("X") events per
        span, pid = region, tid = replica; timestamps in µs.  Per-span
        energy splits the request's attributed energy proportionally to
        span duration."""
        events = []
        for row in self.rows():
            pid = row["region"] or "site"
            tid = int(row["replica"])
            t = row["arrival_s"]
            spans = [("queue", row["queue_s"]),
                     ("kv_load", row["kv_load_s"]),
                     ("prefill", row["prefill_s"]),
                     ("decode", row["decode_s"])]
            total = sum(d for _, d in spans) or 1.0
            for name, dur in spans:
                if dur <= 0.0:
                    continue
                events.append({
                    "name": name, "ph": "X", "pid": pid, "tid": tid,
                    "ts": t * 1e6, "dur": dur * 1e6,
                    "args": {"rid": row["rid"],
                             "hit": row["hit_kind"],
                             "tier": row["tier"],
                             "matched_tokens": row["matched_tokens"],
                             "energy_j": row["energy_j"] * dur / total,
                             "carbon_g": row["carbon_g"] * dur / total},
                })
                t += dur
        for ev in self.events:
            events.append({"name": ev["kind"], "ph": "i",
                           "pid": ev.get("region") or "site", "tid": 0,
                           "ts": ev["ts"] * 1e6, "s": "g",
                           "args": {k: v for k, v in ev.items()
                                    if k not in ("kind", "ts")}})
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)

    # ------------------------------------------------------------------ #
    def summary(self) -> Dict:
        """Aggregate view (what ``tools/trace_report.py`` renders)."""
        n = self.n
        out: Dict = {"requests": n, "events": len(self.events)}
        if not n:
            return out
        hk = self.column("hit_kind")
        out["hits"] = {name: int((hk == code).sum())
                       for name, code in HIT_KIND_CODES.items()
                       if int((hk == code).sum())}
        out["matched_tokens"] = int(self.column("matched_tokens").sum())
        out["prompt_tokens"] = int(self.column("prompt_tokens").sum())
        out["energy_kwh"] = float(self.column("energy_j").sum()) / 3.6e6
        out["carbon_g"] = float(self.column("carbon_g").sum())
        out["ttft"] = self.percentiles("ttft_s")
        out["tpot"] = self.percentiles("tpot_s")
        for k in ("queue_s", "prefill_s", "kv_load_s", "decode_s"):
            out[k] = float(self.column(k).sum())
        ev_kinds: Dict[str, int] = {}
        for ev in self.events:
            ev_kinds[ev["kind"]] = ev_kinds.get(ev["kind"], 0) + 1
        if ev_kinds:
            out["event_kinds"] = ev_kinds
        return out
