"""Streaming quantile estimation — the P² (P-squared) algorithm.

When tracing is on, latency percentiles come from the recorder's exact
column buffers.  When it is off nothing retains the per-request arrays
past each hour, so the day-level p50/p95/p99 on ``RunResult`` use this
constant-memory estimator instead (Jain & Chlamtac 1985): five markers
per quantile, adjusted with a piecewise-parabolic interpolation on
every observation.  Deterministic — same sample stream, same estimate.
"""
from __future__ import annotations

from typing import Dict, Iterable, Sequence

__all__ = ["P2Quantile", "StreamingPercentiles"]


class P2Quantile:
    """One streaming quantile (``q`` in (0, 1)) in O(1) memory."""

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"q must be in (0, 1), got {q}")
        self.q = float(q)
        self.n = 0                       # observations seen
        self._heights: list = []         # marker heights (first 5 samples)
        self._pos = [1, 2, 3, 4, 5]      # marker positions (1-based)
        self._desired = [1.0, 1.0 + 2 * q, 1.0 + 4 * q, 3.0 + 2 * q, 5.0]
        self._inc = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    def add(self, x: float):
        x = float(x)
        self.n += 1
        h = self._heights
        if self.n <= 5:
            h.append(x)
            h.sort()
            return
        # locate the cell, clamping into the marker range
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            self._pos[i] += 1
        for i in range(5):
            self._desired[i] += self._inc[i]
        # adjust interior markers
        for i in (1, 2, 3):
            d = self._desired[i] - self._pos[i]
            if (d >= 1 and self._pos[i + 1] - self._pos[i] > 1) or \
                    (d <= -1 and self._pos[i - 1] - self._pos[i] < -1):
                d = 1 if d > 0 else -1
                hp = self._parabolic(i, d)
                if not h[i - 1] < hp < h[i + 1]:
                    hp = self._linear(i, d)
                h[i] = hp
                self._pos[i] += d

    def _parabolic(self, i: int, d: int) -> float:
        h, p = self._heights, self._pos
        return h[i] + d / (p[i + 1] - p[i - 1]) * (
            (p[i] - p[i - 1] + d) * (h[i + 1] - h[i])
            / (p[i + 1] - p[i])
            + (p[i + 1] - p[i] - d) * (h[i] - h[i - 1])
            / (p[i] - p[i - 1]))

    def _linear(self, i: int, d: int) -> float:
        h, p = self._heights, self._pos
        return h[i] + d * (h[i + d] - h[i]) / (p[i + d] - p[i])

    def extend(self, xs: Iterable[float]):
        for x in xs:
            self.add(x)

    @property
    def value(self) -> float:
        """Current estimate (exact order statistic while n <= 5)."""
        h = self._heights
        if not h:
            return 0.0
        if self.n <= 5:
            # exact small-sample quantile (nearest-rank)
            idx = min(int(self.q * self.n), self.n - 1)
            return float(h[idx])
        return float(h[2])


class StreamingPercentiles:
    """A labelled bundle of P² estimators (default p50/p95/p99) fed with
    per-hour sample arrays; ``values()`` returns ``{"p50": ..., ...}``."""

    def __init__(self, qs: Sequence[float] = (0.50, 0.95, 0.99)):
        self._est = {q: P2Quantile(q) for q in qs}

    def extend(self, xs: Iterable[float]):
        xs = list(xs)
        for est in self._est.values():
            est.extend(xs)

    @property
    def n(self) -> int:
        return next(iter(self._est.values())).n if self._est else 0

    def values(self) -> Dict[str, float]:
        return {f"p{round(q * 100):d}": est.value
                for q, est in self._est.items()}
