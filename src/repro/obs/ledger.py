"""Double-entry carbon ledger — the audit half of the flight recorder.

Every gram of CO₂e in a ``RunResult`` is accrued here at its source
under an (hour, category, region, tier, tenant) key, and the ledger
proves — as a *runtime invariant*, not a benchmark row — that each cut
partitions the run total bit-exactly:

* per **category** (operational / embodied-compute / embodied-cache;
  transition energy is a memo inside operational, where the engine
  prices it),
* per **region** (geo runs: the global hour is the left-fold sum of the
  per-region hours, exactly as ``combine_results`` computed it),
* per **tier** and per **tenant** (the functional-unit and chargeback
  cuts of PR-7/PR-8).

Float addition is not associative, so "bit-exact partition" is enforced
the way the engine's own chargeback does it (``SimResult.per_tenant``):
each partition may carry an ulp-scale *reconciliation residual*, folded
into its final key until the left-fold sum lands exactly on the total.
The fold is tolerance-gated: a residual beyond ``rel_tol`` is not
rounding — it is a dropped array, a mispriced component, or a
non-converging fold (the PR-8 bug class) — and raises ``LedgerError``
instead of being papered over.

``CarbonLedger.from_run(result)`` builds and verifies the ledger for a
finished day; the controller does this automatically at the end of
``run_day`` (``conservation_check=True``, the default).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

__all__ = ["CarbonLedger", "LedgerError", "exact_partition"]

AXES = ("category", "region", "tier", "tenant")

# reconciliation tolerance: anything past this is corruption, not float
# dust.  1e-9 relative covers ~6 decimal orders of headroom above the
# worst re-association error of summing a few dozen doubles.
REL_TOL = 1e-9
_FOLD_ITERS = 16


class LedgerError(AssertionError):
    """A carbon partition failed to reproduce its total: some gram was
    dropped, double-counted, or mispriced between the cut and the bill."""


def _lsum(values) -> float:
    """Plain left-fold sum — the association every verifier uses, and
    the one ``sum()``/``combine_results`` produce."""
    total = 0.0
    for v in values:
        total += v
    return total


def exact_partition(total: float, parts: Dict[str, float], *,
                    rel_tol: float = REL_TOL,
                    where: str = "") -> Dict[str, float]:
    """Reconcile ``parts`` so their left-fold sum reproduces ``total``
    bit-exactly, folding the float-rounding residual into the largest
    part (moved to the end of the dict — correcting the final addend
    leaves every earlier partial sum untouched, so the fixed point
    converges in a step or two).

    Raises ``LedgerError`` when the initial residual exceeds ``rel_tol``
    (relative to the partition's scale) — that is not rounding dust but
    a genuinely broken partition — or when the fold fails to converge.
    """
    total = float(total)
    out = {k: float(v) for k, v in parts.items()}
    scale = max(abs(total), _lsum(abs(v) for v in out.values()), 1e-12)
    resid = total - _lsum(out.values())
    if abs(resid) > rel_tol * scale:
        raise LedgerError(
            f"carbon partition{' (' + where + ')' if where else ''} does "
            f"not reproduce its total: parts sum to "
            f"{_lsum(out.values()):.9g}, total is {total:.9g} "
            f"(residual {resid:.3e} > tol {rel_tol * scale:.3e})")
    if resid == 0.0 or not out:
        if not out and total != 0.0:
            raise LedgerError(
                f"empty partition{' (' + where + ')' if where else ''} "
                f"for nonzero total {total:.9g}")
        return out
    # move the largest-|value| key to the end, then fold into it
    sink = max(out, key=lambda k: abs(out[k]))
    out[sink] = out.pop(sink)
    for _ in range(_FOLD_ITERS):
        resid = total - _lsum(out.values())
        if resid == 0.0:
            return out
        out[sink] += resid
    # ``+=`` can stall one ulp away: when the largest part shares the
    # total's exponent, a round-to-even tie can make *no* value of that
    # part land the final addition exactly on ``total``.  Rebuild
    # through the smallest part instead — the rest-fold then sits in
    # [total/2, 2*total], where Sterbenz's lemma makes ``total - rest``
    # exact, so the final addition reproduces ``total`` bit-for-bit.
    small = min(out, key=lambda k: abs(out[k]))
    out[small] = out.pop(small)
    rest = _lsum(list(out.values())[:-1])
    out[small] = total - rest
    if _lsum(out.values()) == total:
        return out
    # last resort (rest outside the Sterbenz window): ulp-walk
    for _ in range(_FOLD_ITERS * 4):
        resid = total - _lsum(out.values())
        if resid == 0.0:
            return out
        out[small] = math.nextafter(out[small], math.copysign(
            math.inf, resid))
    raise LedgerError(
        f"residual fold failed to converge"
        f"{' (' + where + ')' if where else ''}: total {total:.9g}, "
        f"remaining residual {total - _lsum(out.values()):.3e}")


@dataclass
class HourCell:
    """One hour's audited carbon: the hour total plus one reconciled
    partition per axis. Every dict's left-fold sum equals ``total_g``
    bit-exactly (enforced at construction)."""
    hour: int
    total_g: float
    category: Dict[str, float] = field(default_factory=dict)
    region: Dict[str, float] = field(default_factory=dict)
    tier: Dict[str, float] = field(default_factory=dict)
    tenant: Dict[str, float] = field(default_factory=dict)

    def cut(self, axis: str) -> Dict[str, float]:
        if axis not in AXES:
            raise ValueError(f"axis must be one of {AXES}, got {axis!r}")
        return getattr(self, axis)


class CarbonLedger:
    """Per-hour double-entry carbon records with bit-exact partitions.

    ``add_hour`` reconciles (and therefore audits) each axis at accrual
    time; ``verify`` re-proves every invariant afterwards — useful in
    tests that deliberately corrupt a cell to show the error class
    raises.  ``by(axis)`` returns the day-level cut, itself reconciled
    against ``total_g``.
    """

    def __init__(self, *, rel_tol: float = REL_TOL):
        self.rel_tol = float(rel_tol)
        self.hours: List[HourCell] = []

    # ------------------------------------------------------------------ #
    @property
    def total_g(self) -> float:
        return _lsum(c.total_g for c in self.hours)

    def add_hour(self, hour: int, total_g: float, *,
                 category: Optional[Dict[str, float]] = None,
                 region: Optional[Dict[str, float]] = None,
                 tier: Optional[Dict[str, float]] = None,
                 tenant: Optional[Dict[str, float]] = None) -> HourCell:
        """Accrue one hour.  Omitted axes default to a single-key
        partition (the whole hour under one label) — trivially exact.
        Provided axes are reconciled via ``exact_partition`` and raise
        ``LedgerError`` on corruption."""
        total_g = float(total_g)
        cell = HourCell(hour=int(hour), total_g=total_g)
        defaults = {"category": {"operational": total_g},
                    "region": {"site": total_g},
                    "tier": {"all": total_g},
                    "tenant": {"all": total_g}}
        given = {"category": category, "region": region,
                 "tier": tier, "tenant": tenant}
        for axis in AXES:
            parts = given[axis]
            if parts is None:
                parts = defaults[axis]
            setattr(cell, axis, exact_partition(
                total_g, parts, rel_tol=self.rel_tol,
                where=f"hour {hour} {axis}"))
        self.hours.append(cell)
        return cell

    # ------------------------------------------------------------------ #
    @classmethod
    def from_run(cls, result, *, rel_tol: float = REL_TOL,
                 verify: bool = True) -> "CarbonLedger":
        """Build (and audit) the ledger for a finished ``RunResult``.

        Categories come from the hour's component fields (the engine
        computes ``carbon_g = op + emb_cache + emb_comp`` in exactly
        that order); regions from the per-region day results of a geo
        run (the global hour is their left-fold sum); tiers/tenants
        from the hour's functional-unit/chargeback dicts.  Single-site
        and single-tier hours collapse to one-key partitions."""
        led = cls(rel_tol=rel_tol)
        region_hours = None
        region_names = None
        if getattr(result, "regions", None):
            region_names = list(result.regions)
            region_hours = [result.regions[nm].hours
                            for nm in region_names]
        for i, h in enumerate(result.hours):
            category = {"operational": h.operational_g,
                        "embodied_cache": h.embodied_cache_g,
                        "embodied_compute": h.embodied_compute_g}
            region = None
            if region_hours is not None:
                region = {nm: rh[i].carbon_g
                          for nm, rh in zip(region_names, region_hours)}
            tier = {t: d["carbon_g"] for t, d in h.tiers.items()} \
                if h.tiers else None
            tenant = {t: d["carbon_g"] for t, d in h.tenants.items()} \
                if h.tenants else None
            led.add_hour(h.hour, h.carbon_g, category=category,
                         region=region, tier=tier, tenant=tenant)
        if verify:
            led.verify(expected_total=result.total_carbon_g)
        return led

    # ------------------------------------------------------------------ #
    def by(self, axis: str) -> Dict[str, float]:
        """Day-level cut: per-key sums across hours, reconciled so the
        cut partitions ``total_g`` bit-exactly."""
        agg: Dict[str, float] = {}
        for c in self.hours:
            for k, v in c.cut(axis).items():
                agg[k] = agg.get(k, 0.0) + v
        return exact_partition(self.total_g, agg, rel_tol=self.rel_tol,
                               where=f"day {axis}")

    def verify(self, expected_total: Optional[float] = None
               ) -> "CarbonLedger":
        """Re-prove every invariant: each hour's four partitions sum
        (left-fold) to the hour total bit-exactly; each day cut
        partitions ``total_g``; and ``total_g`` equals the caller's
        expected run total when given.  Raises ``LedgerError``."""
        for c in self.hours:
            for axis in AXES:
                parts = c.cut(axis)
                s = _lsum(parts.values())
                if s != c.total_g:
                    raise LedgerError(
                        f"hour {c.hour} {axis} partition sums to "
                        f"{s:.9g}, hour total is {c.total_g:.9g}")
        for axis in AXES:
            self.by(axis)           # raises if irreconcilable
        if expected_total is not None \
                and float(expected_total) != self.total_g:
            raise LedgerError(
                f"ledger total {self.total_g:.9g} != run total "
                f"{float(expected_total):.9g}")
        return self

    # ------------------------------------------------------------------ #
    def summary(self) -> Dict:
        """Plain-dict audit report (what ``tools/trace_report.py`` and
        the docs render)."""
        return {"hours": len(self.hours), "total_g": self.total_g,
                **{f"by_{axis}": self.by(axis) for axis in AXES}}
