"""Training substrate: data, optimizer, train steps, checkpointing."""
