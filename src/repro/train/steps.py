"""Training step: chunked cross-entropy (never materializes the full
(B, S, vocab) logits — critical for 256k vocabs) + AdamW update."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import forward
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

LOSS_CHUNK = 256
IGNORE_LABEL = -1
# §Perf variant: compute the per-chunk vocab logits in fp32 (True, safest)
# or keep the matmul output in bf16 and upcast only for logsumexp (False —
# halves the loss-chunk HBM traffic; see EXPERIMENTS.md §Perf).
LOGITS_F32 = True


def chunked_softmax_xent(hidden, w_unembed, labels, *, chunk=LOSS_CHUNK):
    """hidden: (B, S, d); labels: (B, S) int32 (IGNORE_LABEL masked).
    Returns (sum_nll, num_tokens)."""
    B, S, d = hidden.shape
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    h = hidden.reshape(B, nc, chunk, d).swapaxes(0, 1)
    lab = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(args):
        hc, lc = args
        logits = hc @ w_unembed                             # (B, chunk, V)
        if LOGITS_F32:
            logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        safe = jnp.maximum(lc, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        mask = (lc != IGNORE_LABEL).astype(jnp.float32)
        return ((logz - gold) * mask).sum(), mask.sum()

    nll, cnt = jax.lax.map(body, (h, lab))
    return nll.sum(), cnt.sum()


def loss_fn(params, cfg: ModelConfig, batch, *, long_context=False):
    hidden, aux = forward(params, cfg, batch, long_context=long_context,
                          remat=True, return_hidden=True, with_aux=True)
    labels = batch["labels"]
    if hidden.shape[1] != labels.shape[1]:      # vlm: loss on text region only
        hidden = hidden[:, hidden.shape[1] - labels.shape[1]:]
    nll, cnt = chunked_softmax_xent(hidden, params["unembed"], labels)
    loss = nll / jnp.maximum(cnt, 1.0)
    metrics = {"loss": loss, "tokens": cnt}
    if "load_balance_loss" in aux:
        loss = loss + 0.01 * aux["load_balance_loss"] \
            + 0.001 * aux["router_z_loss"]
        metrics.update({k: v for k, v in aux.items()})
    metrics["total_loss"] = loss
    return loss, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    *, long_context=False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, long_context=long_context),
            has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    from repro.models.transformer import init_params
    params = init_params(key, cfg, dtype)
    return params, adamw_init(params)
