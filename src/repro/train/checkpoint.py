"""Msgpack-based checkpointing for param/optimizer pytrees.

Layout: a directory with ``manifest.msgpack`` (treedef + shapes/dtypes) and
one raw ``.npy``-style blob per leaf (streamed, no 2× memory)."""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(path: str, tree, step: int = 0):
    os.makedirs(path, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fname = key.replace("/", "__") + ".bin"
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(path, fname), "wb") as f:
            f.write(arr.tobytes())
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))


def restore_checkpoint(path: str, like_tree) -> Tuple[Any, int]:
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    flat_like = _flatten_with_paths(like_tree)
    restored = {}
    for key, meta in manifest["leaves"].items():
        with open(os.path.join(path, meta["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=np.dtype(meta["dtype"]))
        restored[key] = jnp.asarray(arr.reshape(meta["shape"]))
    if set(restored) != set(flat_like):
        missing = set(flat_like) ^ set(restored)
        raise ValueError(f"checkpoint/tree structure mismatch: {missing}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    keys = list(_flatten_with_paths(like_tree).keys())
    new_leaves = [restored[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), \
        manifest["step"]
