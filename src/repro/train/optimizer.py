"""AdamW in pure JAX (functional, pytree-based).

Moments are fp32 regardless of param dtype; the update is computed in fp32
and cast back, so bf16 training is stable without a separate master copy
(moment + update precision dominates).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(f32, params),
                      nu=jax.tree.map(f32, params))


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_mu, new_nu), metrics
