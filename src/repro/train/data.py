"""Synthetic language-modeling data pipeline.

Generates token streams from a Zipf-distributed "vocabulary of phrases" with
Markov structure so small models have real signal to learn (loss decreases),
then packs them into fixed-length (tokens, labels) batches. Deterministic
per seed; infinite iterator; supports vlm/encdec extras via
``make_batch_for``.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticCorpus:
    """Order-1 Markov chain over the vocab with Zipf marginals."""

    def __init__(self, vocab_size: int, seed: int = 0, branching: int = 32):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        self.branch = np.minimum(branching, vocab_size)
        # each token transitions to one of `branching` successors
        self.successors = rng.integers(0, vocab_size,
                                       size=(vocab_size, self.branch))
        w = 1.0 / np.arange(1, self.branch + 1) ** 1.1
        self.probs = w / w.sum()

    def stream(self, seed: int) -> Iterator[int]:
        rng = np.random.default_rng(seed)
        tok = int(rng.integers(0, self.vocab))
        while True:
            yield tok
            nxt = rng.choice(self.branch, p=self.probs)
            tok = int(self.successors[tok, nxt])


def batch_iterator(cfg: ModelConfig, batch: int, seq: int, seed: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    corpus = SyntheticCorpus(cfg.vocab_size, seed)
    streams = [corpus.stream(seed + i) for i in range(batch)]
    while True:
        toks = np.array([[next(s) for _ in range(seq + 1)] for s in streams],
                        dtype=np.int32)
        yield make_batch_for(cfg, toks[:, :-1], toks[:, 1:])


def make_batch_for(cfg: ModelConfig, tokens: np.ndarray,
                   labels: np.ndarray) -> Dict[str, np.ndarray]:
    """Add family extras (stub frontends) to a token batch."""
    B, S = tokens.shape
    batch: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels}
    rng = np.random.default_rng(int(tokens[0, 0]) + 7)
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (B, cfg.source_len, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.family == "vlm":
        V = cfg.vision_tokens
        batch["patches"] = rng.standard_normal(
            (B, V, cfg.d_model)).astype(np.float32) * 0.02
        pos = np.arange(S + V)[None, :, None]
        batch["positions"] = np.broadcast_to(pos, (B, S + V, 3)).astype(
            np.int32).copy()
    return batch
