"""recurrentgemma-2b — Griffin hybrid: RG-LRU recurrent blocks + local
attention, ratio 1 attn : 2 recurrent. 26 layers = 8×(rec,rec,attn) + 2 rec.
[arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,             # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    activation="gelu",
    gated_mlp=True,
    griffin=True,
    rnn_width=2560,
    conv_width=4,
    local_window=2048,
    source="arXiv:2402.19427",
)
