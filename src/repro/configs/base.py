"""Config system: model architecture configs + canonical input shapes.

Every assigned architecture has a module ``repro/configs/<id>.py`` exporting
``CONFIG``; the registry in ``repro.configs`` maps arch ids to them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. All sizes are *exact* per the assignment;
    padding (vocab, heads) happens inside the model, never here."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # MLP
    activation: str = "silu"         # silu | gelu | relu2
    gated_mlp: bool = True

    # attention
    rope_theta: float = 10_000.0
    window_size: Optional[int] = None       # sliding window (SWA archs)
    long_context_window: int = 8192         # window used in long_500k mode

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # hybrid (Griffin / RecurrentGemma)
    griffin: bool = False
    rnn_width: int = 0
    conv_width: int = 4
    local_window: int = 2048                # local-attn window in griffin blocks

    # ssm (RWKV6)
    rwkv_head_dim: int = 64

    # enc-dec
    encoder_layers: int = 0
    source_len: int = 1024                  # encoder memory length (stub frontend)

    # vlm
    mrope: bool = False
    vision_tokens: int = 0                  # prefix patch-embedding tokens (stub)

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""                        # citation

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the logit dim shards over the model axis."""
        return _round_up(self.vocab_size, 128)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def num_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def kv_bytes_per_token(self) -> int:
        """bf16 K+V bytes per cached token (dense layers)."""
        if self.attn_free:
            return 0
        return self.num_layers * self.num_kv_heads * self.head_dim * 2 * 2

    @property
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            # tmix ~ 5 d^2 (+ low-rank extras), cmix ~ 2*d*d_ff-ish
            blk = 5 * d * d + 2 * d * self.d_ff
            return emb + L * blk
        attn = d * self.num_heads * self.head_dim * 2 + \
            d * self.num_kv_heads * self.head_dim * 2
        mlp = d * self.d_ff * (3 if self.gated_mlp else 2)
        if self.family == "moe":
            mlp = mlp * self.num_experts + d * self.num_experts
        blk = attn + mlp
        if self.family == "hybrid":
            # 2/3 of layers are RG-LRU blocks (~4 d*rnn) instead of attention
            rec = 4 * d * self.rnn_width
            blk = (attn + mlp + 2 * (rec + mlp)) / 3.0
        total = emb + L * blk
        if self.encoder_layers:
            total += self.encoder_layers * (attn * 1.5 + mlp)  # self+cross attn
        return int(total)

    @property
    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.num_heads * self.head_dim * 2 + \
            d * self.num_kv_heads * self.head_dim * 2
        mlp = d * self.d_ff * (3 if self.gated_mlp else 2) * self.experts_per_token
        return int(emb + L * (attn + mlp + d * self.num_experts))

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                max_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: same family/feature-set, tiny dims."""
        heads = 0 if self.attn_free else max(2, min(4, self.num_heads))
        head_dim = d_model // max(heads, 4)
        kv = 0 if self.attn_free else max(1, min(self.num_kv_heads, heads))
        changes = dict(
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=d_model * 2,
            vocab_size=512,
            window_size=64 if self.window_size else None,
            long_context_window=128,
            local_window=32,
            rnn_width=d_model if self.griffin else 0,
            rwkv_head_dim=32,
            encoder_layers=1 if self.encoder_layers else 0,
            source_len=16 if self.encoder_layers else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            num_experts=min(self.num_experts, max_experts) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token else 0,
        )
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int
    long_context: bool = False


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1, long_context=True),
}
