"""minitron-8b — pruned Nemotron-4 dense GQA model. [arXiv:2407.14679]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    activation="relu2",         # nemotron family: squared-ReLU, non-gated
    gated_mlp=False,
    source="arXiv:2407.14679",
)
