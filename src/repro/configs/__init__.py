"""Architecture registry: ``get_config("dbrx-132b")`` etc."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

# arch id -> module name
_ARCH_MODULES = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "minitron-8b": "minitron_8b",
    "nemotron-4-15b": "nemotron_4_15b",
    "yi-6b": "yi_6b",
    "dbrx-132b": "dbrx_132b",
    "grok-1-314b": "grok_1_314b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    # the paper's own evaluation models
    "llama3-70b": "llama3_70b",
    "llama3-8b": "llama3_8b",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if not k.startswith("llama3"))
ALL_ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES",
    "get_config", "ASSIGNED_ARCHS", "ALL_ARCHS",
]
