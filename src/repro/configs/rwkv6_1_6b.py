"""rwkv6-1.6b (Finch) — attention-free RNN with data-dependent decay,
token-shift, and matrix-valued WKV state. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=0,                # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=7168,
    vocab_size=65536,
    activation="relu2",         # rwkv channel-mix uses squared relu
    gated_mlp=False,
    rwkv_head_dim=64,
    source="arXiv:2404.05892",
)
