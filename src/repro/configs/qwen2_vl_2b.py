"""qwen2-vl-2b — VLM language backbone with M-RoPE (temporal/h/w rotary
sections) and dynamic-resolution vision tokens. The ViT encoder + projector is
a stub: ``input_specs`` supplies precomputed patch embeddings.
[arXiv:2409.12191]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    activation="silu",
    gated_mlp=True,
    mrope=True,
    vision_tokens=1024,
    source="arXiv:2409.12191",
)
