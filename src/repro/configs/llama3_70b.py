"""llama3-70b — the paper's primary evaluation model (Meta Llama-3 70B).
[arXiv:2407.21783]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    activation="silu",
    gated_mlp=True,
    rope_theta=500000.0,
    source="arXiv:2407.21783",
)
