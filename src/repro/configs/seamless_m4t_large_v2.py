"""seamless-m4t-large-v2 — encoder-decoder multimodal (audio) backbone.
24 transformer layers total: 12 encoder + 12 decoder with cross-attention.
The speech frontend (mel-spectrogram + conv feature extractor) is a stub:
``input_specs`` supplies precomputed frame embeddings. [arXiv:2308.11596]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=12,              # decoder layers (12 enc + 12 dec = 24L total)
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    activation="gelu",
    gated_mlp=False,
    source_len=1024,            # encoder frames (stub frontend output)
    source="arXiv:2308.11596",
)
