"""GreenCache reproduction: carbon-aware KV-cache management for LLM
serving (simulation + real-execution JAX/Pallas substrate)."""

__version__ = "0.1.0"
