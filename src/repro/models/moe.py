"""Mixture-of-Experts FFN with capacity-based scatter dispatch (GShard-style).

Dispatch is implemented with scatter/gather (not a dense (T,E,C) one-hot
einsum) so the dispatch buffers stay O(E·C·d) — the pattern GSPMD lowers to
the expert-parallel all-to-all we analyze in the roofline.

Sharding modes (set by whether num_experts divides the model axis):
  * EP  — experts sharded 1-per-device over `model` (dbrx: 16e on 16-way)
  * TP  — experts replicated, d_ff sharded over `model` (grok: 8e on 16-way)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import activation_fn, dense_init


# §Perf experiment: constrain dispatch buffers to expert-parallel sharding
# so GSPMD reduce-scatters the token contributions instead of all-reducing
# the full (E, C, d) buffer (see EXPERIMENTS.md §Perf pair 3).
BUF_CONSTRAINT = False


def _maybe_constrain(x, spec):
    if not BUF_CONSTRAINT:
        return x
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def moe_capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(cfg.moe_capacity_factor * num_tokens * cfg.experts_per_token
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, keep a floor


def init_moe(key, cfg: ModelConfig, dtype):
    d, dff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),  # router kept fp32
        "w_up": _expert_init(ks[1], E, d, dff, dtype),
        "w_down": _expert_init(ks[2], E, dff, d, dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _expert_init(ks[3], E, d, dff, dtype)
    return p


def _expert_init(key, E, d_in, d_out, dtype):
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (E, d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def moe_ffn(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d), plus aux losses dict."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = moe_capacity(cfg, T)
    xt = x.reshape(T, d)

    logits = xt.astype(jnp.float32) @ params["router"]        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                    # (T, K)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    # flatten (token, slot) assignments
    eid = top_e.reshape(T * K)                                # expert id
    gate = top_p.reshape(T * K)
    tok = jnp.repeat(jnp.arange(T), K)

    # position of each assignment within its expert (capacity check)
    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)          # (T*K, E)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_all, eid[:, None], axis=1)[:, 0]
    keep = pos < C
    pos = jnp.where(keep, pos, 0)

    # scatter tokens into (E, C, d) expert buffers
    contrib = jnp.where(keep[:, None], xt[tok], 0).astype(x.dtype)
    buf = jnp.zeros((E, C, d), x.dtype).at[eid, pos].add(
        contrib, mode="drop")
    buf = _maybe_constrain(buf, ("model", None, None))

    # per-expert FFN, batched over E
    act = activation_fn(cfg.activation)
    h = act(jnp.einsum("ecd,edf->ecf", buf, params["w_up"]))
    if cfg.gated_mlp:
        h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, C, d)

    # gather back and combine weighted by gate
    gathered = out_buf[eid, pos]                               # (T*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered * gate[:, None].astype(gathered.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(weighted.astype(x.dtype))

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                    # (E,)
    ce = (jnp.sum(jax.nn.one_hot(top_e, E), axis=(0, 1)) / (T * K))
    aux = {"load_balance_loss": E * jnp.sum(me * ce),
           "router_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
           "dropped_frac": 1.0 - keep.mean()}
    return y.reshape(B, S, d), aux


def moe_ffn_ref(params, x, cfg: ModelConfig):
    """Oracle: per-token dense routing (computes every expert on every token).
    Used only in tests to validate the dispatch path (with capacity high
    enough that nothing drops)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)
    act = activation_fn(cfg.activation)
    h = act(jnp.einsum("td,edf->tef", xt, params["w_up"]))
    if cfg.gated_mlp:
        h = h * jnp.einsum("td,edf->tef", xt, params["w_gate"])
    all_out = jnp.einsum("tef,efd->ted", h, params["w_down"])  # (T, E, d)
    w = jnp.zeros(probs.shape, jnp.float32)
    w = jnp.take_along_axis(
        jnp.zeros_like(probs).at[
            jnp.arange(xt.shape[0])[:, None], top_e].set(top_p),
        jnp.arange(E)[None, :], axis=1)
    y = jnp.einsum("ted,te->td", all_out.astype(jnp.float32), w)
    return y.reshape(B, S, d).astype(x.dtype)
