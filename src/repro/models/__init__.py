from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, prefill)

__all__ = ["init_params", "forward", "init_cache", "prefill", "decode_step"]
