"""Unified functional transformer covering all six assigned families.

Public API (all pure functions):
    init_params(key, cfg, dtype)                          -> params
    forward(params, cfg, batch, ...)                      -> logits (train path)
    init_cache(cfg, batch, max_len, dtype, long_context)  -> cache
    prefill(params, cfg, batch, max_len, ...)             -> (logits, cache)
    decode_step(params, cfg, cache, tokens, pos, ...)     -> (logits, cache)

``batch`` is a dict: tokens (B,S) int32, plus family extras:
    encdec: frames  (B, src, d)   — stubbed audio frontend output
    vlm:    patches (B, V, d)     — stubbed vision encoder output
            positions (B, S, 3)   — M-RoPE position ids

Layer stacks are scanned (params stacked on a leading L axis) with optional
remat, so compiled HLO stays one-layer-sized for the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import griffin as gr
from repro.models import rwkv6 as rw
from repro.models.common import (apply_mrope, apply_rope, attention,
                                 decode_attend, dense_init, embed_init,
                                 init_attention, init_mlp, init_rmsnorm, mlp,
                                 rmsnorm)
from repro.models.moe import init_moe, moe_ffn

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# helpers
# --------------------------------------------------------------------------- #

def _stack_init(fn, key, n: int):
    """vmap an init over n layer keys -> params stacked on leading axis."""
    return jax.vmap(fn)(jax.random.split(key, n))


def attn_window(cfg: ModelConfig, long_context: bool) -> Optional[int]:
    """Effective sliding window for dense-ish self-attention."""
    if long_context:
        w = cfg.long_context_window
        if cfg.window_size:
            w = min(w, cfg.window_size)
        return w
    return cfg.window_size


def cache_width(cfg: ModelConfig, max_len: int, long_context: bool) -> int:
    w = attn_window(cfg, long_context)
    return min(max_len, w) if w else max_len


def ring_kpos(width: int, pos):
    """Absolute position held by each ring-buffer slot at decode step `pos`.
    slot i holds p = pos - ((pos - i) mod width); p < 0 -> empty."""
    i = jnp.arange(width)
    return pos - jnp.mod(pos - i, width)


# --------------------------------------------------------------------------- #
# generic attention layer (dense / moe / vlm / encdec-self / griffin-local)
# --------------------------------------------------------------------------- #

def _init_attn_layer(key, cfg: ModelConfig, dtype, *, use_moe: bool):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
    }
    if use_moe:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[2], cfg, dtype)
    return p


def _qkv(p, cfg: ModelConfig, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    return q, k, v


def _rope_qk(cfg: ModelConfig, q, k, positions, mrope_positions=None):
    if cfg.mrope and mrope_positions is not None:
        return (apply_mrope(q, mrope_positions, cfg.rope_theta),
                apply_mrope(k, mrope_positions, cfg.rope_theta))
    return (apply_rope(q, positions, cfg.rope_theta),
            apply_rope(k, positions, cfg.rope_theta))


def _attn_layer_fwd(p, cfg: ModelConfig, x, *, window, q_offset=0,
                    mrope_positions=None, prefix_kv=None, return_kv=False):
    """Residual attention sub-block + FFN sub-block (full sequence)."""
    B, S, _ = x.shape
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = _qkv(p["attn"], cfg, h)
    positions = q_offset + jnp.arange(S)
    q, k = _rope_qk(cfg, q, k, positions, mrope_positions)
    if prefix_kv is not None:                      # cached-context prefill
        k = jnp.concatenate([prefix_kv[0], k], axis=1)
        v = jnp.concatenate([prefix_kv[1], v], axis=1)
    o = attention(q, k, v, q_offset=q_offset, window=window)
    x = x + o.reshape(B, S, -1) @ p["attn"]["wo"]

    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = {}
    if "moe" in p:
        y, aux = moe_ffn(p["moe"], h2, cfg)
    else:
        y = mlp(p["mlp"], h2, cfg)
    x = x + y
    if return_kv:
        return x, (k, v), aux
    return x, aux


def _attn_layer_decode(p, cfg: ModelConfig, x_t, k_cache, v_cache, pos, *,
                       window, mrope_positions=None):
    """x_t: (B,1,d); caches: (B,W,KV,hd); pos scalar."""
    B = x_t.shape[0]
    W = k_cache.shape[1]
    h = rmsnorm(p["ln1"], x_t, cfg.norm_eps)
    q, k, v = _qkv(p["attn"], cfg, h)
    pos_arr = jnp.full((1,), pos)
    q, k = _rope_qk(cfg, q, k, pos_arr, mrope_positions)
    slot = jnp.mod(pos, W)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, slot, 0, 0))
    kpos = ring_kpos(W, pos)
    o = decode_attend(q, k_cache, v_cache, kpos, pos, window=window)
    x_t = x_t + o.reshape(B, 1, -1) @ p["attn"]["wo"]

    h2 = rmsnorm(p["ln2"], x_t, cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_ffn(p["moe"], h2, cfg)
    else:
        y = mlp(p["mlp"], h2, cfg)
    return x_t + y, k_cache, v_cache


# --------------------------------------------------------------------------- #
# RWKV6 layer
# --------------------------------------------------------------------------- #

def _init_rwkv_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "tmix": rw.init_time_mix(ks[0], cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "cmix": rw.init_channel_mix(ks[1], cfg, dtype),
    }


def _rwkv_layer_fwd(p, cfg, x, state):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    o, x_tm, wkv = rw.time_mix(p["tmix"], cfg, h, state["x_tm"], state["wkv"])
    x = x + o
    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    o2, x_cm = rw.channel_mix(p["cmix"], h2, state["x_cm"])
    x = x + o2
    return x, {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}


def _rwkv_empty_state(cfg: ModelConfig, B: int, dtype):
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    return {"wkv": jnp.zeros((B, H, hd, hd), jnp.float32),
            "x_tm": jnp.zeros((B, cfg.d_model), dtype),
            "x_cm": jnp.zeros((B, cfg.d_model), dtype)}


# --------------------------------------------------------------------------- #
# Griffin unit (rec, rec, local-attn), each with its own MLP
# --------------------------------------------------------------------------- #

def _init_rec_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": init_rmsnorm(cfg.d_model, dtype),
            "rg": gr.init_rglru_block(ks[0], cfg, dtype),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(ks[1], cfg, dtype)}


def _rec_layer_fwd(p, cfg, x, state):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    o, state = gr.rglru_block(p["rg"], h, state)
    x = x + o
    x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x, state


def _rec_layer_decode(p, cfg, x_t, state):
    h = rmsnorm(p["ln1"], x_t, cfg.norm_eps)
    o, state = gr.rglru_block_step(p["rg"], h[:, 0], state)
    x_t = x_t + o[:, None]
    x_t = x_t + mlp(p["mlp"], rmsnorm(p["ln2"], x_t, cfg.norm_eps), cfg)
    return x_t, state


def _init_griffin_unit(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"rec1": _init_rec_layer(ks[0], cfg, dtype),
            "rec2": _init_rec_layer(ks[1], cfg, dtype),
            "attn": _init_attn_layer(ks[2], cfg, dtype, use_moe=False)}


def griffin_layout(cfg: ModelConfig):
    """(num_units, num_tail_rec) such that 3*U + tail == num_layers."""
    units = cfg.num_layers // 3
    tail = cfg.num_layers - 3 * units
    return units, tail


# --------------------------------------------------------------------------- #
# enc-dec layers
# --------------------------------------------------------------------------- #

def _init_enc_layer(key, cfg, dtype):
    return _init_attn_layer(key, cfg, dtype, use_moe=False)


def _enc_layer_fwd(p, cfg, x):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = _qkv(p["attn"], cfg, h)
    positions = jnp.arange(x.shape[1])
    q, k = _rope_qk(cfg, q, k, positions)
    o = attention(q, k, v, causal=False)           # bidirectional
    x = x + o.reshape(x.shape[0], x.shape[1], -1) @ p["attn"]["wo"]
    x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x


def _init_dec_layer(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "self_attn": init_attention(ks[0], cfg, dtype),
        "ln_x": init_rmsnorm(cfg.d_model, dtype),
        "cross_attn": init_attention(ks[1], cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(ks[2], cfg, dtype),
    }


def _dec_layer_fwd(p, cfg, x, memory, *, window=None, return_kv=False):
    B, S, _ = x.shape
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = _qkv(p["self_attn"], cfg, h)
    positions = jnp.arange(S)
    q, k = _rope_qk(cfg, q, k, positions)
    o = attention(q, k, v, window=window)
    x = x + o.reshape(B, S, -1) @ p["self_attn"]["wo"]

    hx = rmsnorm(p["ln_x"], x, cfg.norm_eps)
    qx = (hx @ p["cross_attn"]["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    ck = (memory @ p["cross_attn"]["wk"]).reshape(
        B, -1, cfg.num_kv_heads, cfg.head_dim)
    cv = (memory @ p["cross_attn"]["wv"]).reshape(
        B, -1, cfg.num_kv_heads, cfg.head_dim)
    ox = attention(qx, ck, cv, causal=False)
    x = x + ox.reshape(B, S, -1) @ p["cross_attn"]["wo"]

    x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    if return_kv:
        return x, (k, v, ck, cv)
    return x


def _dec_layer_decode(p, cfg, x_t, sk, sv, ck, cv, pos, *, window=None):
    B = x_t.shape[0]
    W = sk.shape[1]
    h = rmsnorm(p["ln1"], x_t, cfg.norm_eps)
    q, k, v = _qkv(p["self_attn"], cfg, h)
    q, k = _rope_qk(cfg, q, k, jnp.full((1,), pos))
    slot = jnp.mod(pos, W)
    sk = jax.lax.dynamic_update_slice(sk, k, (0, slot, 0, 0))
    sv = jax.lax.dynamic_update_slice(sv, v, (0, slot, 0, 0))
    o = decode_attend(q, sk, sv, ring_kpos(W, pos), pos, window=window)
    x_t = x_t + o.reshape(B, 1, -1) @ p["self_attn"]["wo"]

    hx = rmsnorm(p["ln_x"], x_t, cfg.norm_eps)
    qx = (hx @ p["cross_attn"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    src = ck.shape[1]
    ox = decode_attend(qx, ck, cv, jnp.arange(src), jnp.asarray(src))
    x_t = x_t + ox.reshape(B, 1, -1) @ p["cross_attn"]["wo"]

    x_t = x_t + mlp(p["mlp"], rmsnorm(p["ln2"], x_t, cfg.norm_eps), cfg)
    return x_t, sk, sv


# --------------------------------------------------------------------------- #
# top level: init
# --------------------------------------------------------------------------- #

def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 8)
    V, d = cfg.padded_vocab, cfg.d_model
    p: Params = {
        "embed": embed_init(ks[0], V, d, dtype),
        "final_ln": init_rmsnorm(d, dtype),
        "unembed": dense_init(ks[1], d, V, dtype),
    }
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        use_moe = fam == "moe"
        p["layers"] = _stack_init(
            lambda k: _init_attn_layer(k, cfg, dtype, use_moe=use_moe),
            ks[2], cfg.num_layers)
        if fam == "vlm":
            p["patch_proj"] = dense_init(ks[3], d, d, dtype)
    elif fam == "ssm":
        p["layers"] = _stack_init(
            lambda k: _init_rwkv_layer(k, cfg, dtype), ks[2], cfg.num_layers)
    elif fam == "hybrid":
        U, tail = griffin_layout(cfg)
        p["units"] = _stack_init(
            lambda k: _init_griffin_unit(k, cfg, dtype), ks[2], U)
        if tail:
            p["tail"] = _stack_init(
                lambda k: _init_rec_layer(k, cfg, dtype), ks[3], tail)
    elif fam == "encdec":
        p["frames_proj"] = dense_init(ks[3], d, d, dtype)
        p["encoder"] = _stack_init(
            lambda k: _init_enc_layer(k, cfg, dtype), ks[4], cfg.encoder_layers)
        p["enc_ln"] = init_rmsnorm(d, dtype)
        p["decoder"] = _stack_init(
            lambda k: _init_dec_layer(k, cfg, dtype), ks[5], cfg.num_layers)
    else:
        raise ValueError(fam)
    return p


# --------------------------------------------------------------------------- #
# top level: full-sequence forward (training / no-cache prefill)
# --------------------------------------------------------------------------- #

def _embed_sequence(params, cfg: ModelConfig, batch):
    """Token (+ modality-stub) embedding -> (B, S, d)."""
    x = params["embed"][batch["tokens"]]
    if cfg.family == "vlm" and "patches" in batch:
        vis = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([vis, x], axis=1)
    return x


def _encode(params, cfg: ModelConfig, frames):
    x = frames @ params["frames_proj"]

    def body(carry, lp):
        return _enc_layer_fwd(lp, cfg, carry), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def forward(params: Params, cfg: ModelConfig, batch, *, long_context=False,
            remat=True, return_hidden=False, with_aux=False):
    """Full-sequence logits (B, S_total, padded_vocab).

    return_hidden: return post-final-norm hidden states instead of logits
    (training computes the vocab projection chunked — see train.steps).
    with_aux: also return dict of per-layer aux (MoE load-balance losses).
    """
    fam = cfg.family
    x = _embed_sequence(params, cfg, batch)
    window = attn_window(cfg, long_context)
    mrope_positions = batch.get("positions") if cfg.mrope else None
    aux_out: Dict[str, Any] = {}

    ck = jax.checkpoint if remat else (lambda f: f)

    if fam in ("dense", "moe", "vlm"):
        def body(carry, lp):
            y, aux = _attn_layer_fwd(lp, cfg, carry, window=window,
                                     mrope_positions=mrope_positions)
            return y, aux
        x, auxs = jax.lax.scan(ck(body), x, params["layers"])
        if with_aux and auxs:
            aux_out = {k: jnp.mean(v) for k, v in auxs.items()}

    elif fam == "ssm":
        B = x.shape[0]
        st = _rwkv_empty_state(cfg, B, x.dtype)

        def body(carry, lp):
            y, _ = _rwkv_layer_fwd(lp, cfg, carry, st)
            return y, None
        x, _ = jax.lax.scan(ck(body), x, params["layers"])

    elif fam == "hybrid":
        B = x.shape[0]
        rst = gr.init_recurrent_state(cfg, B, x.dtype)

        def unit_body(carry, up):
            y = carry
            y, _ = _rec_layer_fwd(up["rec1"], cfg, y, rst)
            y, _ = _rec_layer_fwd(up["rec2"], cfg, y, rst)
            y, _ = _attn_layer_fwd(up["attn"], cfg, y, window=cfg.local_window)
            return y, None
        x, _ = jax.lax.scan(ck(unit_body), x, params["units"])
        if "tail" in params:
            def tail_body(carry, lp):
                y, _ = _rec_layer_fwd(lp, cfg, carry, rst)
                return y, None
            x, _ = jax.lax.scan(ck(tail_body), x, params["tail"])

    elif fam == "encdec":
        memory = _encode(params, cfg, batch["frames"].astype(x.dtype))

        def body(carry, lp):
            return _dec_layer_fwd(lp, cfg, carry, memory, window=window), None
        x, _ = jax.lax.scan(ck(body), x, params["decoder"])

    x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
    out = x if return_hidden else x @ params["unembed"]
    if with_aux:
        return out, aux_out
    return out


# --------------------------------------------------------------------------- #
# top level: cache init / prefill / decode
# --------------------------------------------------------------------------- #

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16, long_context=False):
    B, L = batch_size, cfg.num_layers
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        W = cache_width(cfg, max_len, long_context)
        return {"k": jnp.zeros((L, B, W, KV, hd), dtype),
                "v": jnp.zeros((L, B, W, KV, hd), dtype)}
    if fam == "ssm":
        H, rhd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
        return {"wkv": jnp.zeros((L, B, H, rhd, rhd), jnp.float32),
                "x_tm": jnp.zeros((L, B, cfg.d_model), dtype),
                "x_cm": jnp.zeros((L, B, cfg.d_model), dtype)}
    if fam == "hybrid":
        U, tail = griffin_layout(cfg)
        Wl = min(max_len, cfg.local_window)
        dr, cw = cfg.rnn_width, cfg.conv_width
        cache = {"units": {
            "rec1_h": jnp.zeros((U, B, dr), jnp.float32),
            "rec1_conv": jnp.zeros((U, B, cw - 1, dr), dtype),
            "rec2_h": jnp.zeros((U, B, dr), jnp.float32),
            "rec2_conv": jnp.zeros((U, B, cw - 1, dr), dtype),
            "k": jnp.zeros((U, B, Wl, KV, hd), dtype),
            "v": jnp.zeros((U, B, Wl, KV, hd), dtype)}}
        if tail:
            cache["tail"] = {
                "h": jnp.zeros((tail, B, dr), jnp.float32),
                "conv": jnp.zeros((tail, B, cw - 1, dr), dtype)}
        return cache
    if fam == "encdec":
        W = cache_width(cfg, max_len, long_context)
        src = cfg.source_len
        return {"self_k": jnp.zeros((L, B, W, KV, hd), dtype),
                "self_v": jnp.zeros((L, B, W, KV, hd), dtype),
                "cross_k": jnp.zeros((L, B, src, KV, hd), dtype),
                "cross_v": jnp.zeros((L, B, src, KV, hd), dtype)}
    raise ValueError(fam)


def _place_kv_in_ring(k_full, W: int):
    """k_full: (B, S, KV, hd) -> ring cache (B, W, KV, hd) holding the last
    min(S, W) tokens at slots pos % W."""
    B, S = k_full.shape[:2]
    if S <= W:
        pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
        return jnp.pad(k_full, pad)
    last = k_full[:, S - W:]
    ps = jnp.arange(S - W, S) % W
    return jnp.zeros((B, W) + k_full.shape[2:], k_full.dtype).at[:, ps].set(last)


def prefill(params: Params, cfg: ModelConfig, batch, max_len: int, *,
            long_context=False, prefix_cache=None, prefix_len: int = 0):
    """Process a full prompt, returning (logits, cache) ready for decode.

    prefix_cache/prefix_len: reuse a stored KV prefix (the paper's cache-hit
    path) — new tokens attend to prefix keys with q_offset = prefix_len.
    Dense-family only (recurrent families snapshot whole states instead).
    """
    fam = cfg.family
    x = _embed_sequence(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    window = attn_window(cfg, long_context)
    mrope_positions = batch.get("positions") if cfg.mrope else None
    W = cache_width(cfg, max_len, long_context)

    if fam in ("dense", "moe", "vlm"):
        if prefix_cache is not None:
            pk = prefix_cache["k"][:, :, :prefix_len]
            pv = prefix_cache["v"][:, :, :prefix_len]
        else:
            pk = pv = None

        def body(carry, xs):
            if pk is not None:
                lp, pkl, pvl = xs
                prefix_kv = (pkl, pvl)
            else:
                lp = xs
                prefix_kv = None
            y, (k, v), _ = _attn_layer_fwd(
                lp, cfg, carry, window=window, q_offset=prefix_len,
                mrope_positions=mrope_positions, prefix_kv=prefix_kv,
                return_kv=True)
            return y, (_place_kv_in_ring(k, W), _place_kv_in_ring(v, W))

        xs = (params["layers"], pk, pv) if pk is not None else params["layers"]
        x, (kc, vc) = jax.lax.scan(body, x, xs)
        cache = {"k": kc, "v": vc}

    elif fam == "ssm":
        st0 = _rwkv_empty_state(cfg, B, x.dtype)

        def body(carry, lp):
            y, st = _rwkv_layer_fwd(lp, cfg, carry, st0)
            return y, st
        x, sts = jax.lax.scan(body, x, params["layers"])
        cache = {"wkv": sts["wkv"], "x_tm": sts["x_tm"], "x_cm": sts["x_cm"]}

    elif fam == "hybrid":
        rst0 = gr.init_recurrent_state(cfg, B, x.dtype)
        Wl = min(max_len, cfg.local_window)

        def unit_body(carry, up):
            y = carry
            y, s1 = _rec_layer_fwd(up["rec1"], cfg, y, rst0)
            y, s2 = _rec_layer_fwd(up["rec2"], cfg, y, rst0)
            y, (k, v), _ = _attn_layer_fwd(up["attn"], cfg, y,
                                           window=cfg.local_window,
                                           return_kv=True)
            out = {"rec1_h": s1["h"], "rec1_conv": s1["conv"],
                   "rec2_h": s2["h"], "rec2_conv": s2["conv"],
                   "k": _place_kv_in_ring(k, Wl),
                   "v": _place_kv_in_ring(v, Wl)}
            return y, out
        x, ucache = jax.lax.scan(unit_body, x, params["units"])
        cache = {"units": ucache}
        if "tail" in params:
            def tail_body(carry, lp):
                y, st = _rec_layer_fwd(lp, cfg, carry, rst0)
                return y, st
            x, tsts = jax.lax.scan(tail_body, x, params["tail"])
            cache["tail"] = {"h": tsts["h"], "conv": tsts["conv"]}

    elif fam == "encdec":
        memory = _encode(params, cfg, batch["frames"].astype(x.dtype))

        def body(carry, lp):
            y, (k, v, ckv, cvv) = _dec_layer_fwd(lp, cfg, carry, memory,
                                                 window=window, return_kv=True)
            return y, (_place_kv_in_ring(k, W), _place_kv_in_ring(v, W),
                       ckv, cvv)
        x, (sk, sv, ck_, cv_) = jax.lax.scan(body, x, params["decoder"])
        cache = {"self_k": sk, "self_v": sv, "cross_k": ck_, "cross_v": cv_}
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
    return x @ params["unembed"], cache


def decode_step(params: Params, cfg: ModelConfig, cache, tokens, pos, *,
                long_context=False, mrope_positions=None):
    """One autoregressive step. tokens: (B,1) int32; pos: scalar int32 —
    the absolute position being written. Returns (logits (B,1,V), cache)."""
    fam = cfg.family
    x = params["embed"][tokens]
    window = attn_window(cfg, long_context)
    if cfg.mrope and mrope_positions is None:
        B = tokens.shape[0]
        mrope_positions = jnp.broadcast_to(
            jnp.full((1, 1, 3), 0, jnp.int32) + pos, (B, 1, 3))

    if fam in ("dense", "moe", "vlm"):
        def body(carry, xs):
            lp, kc, vc = xs
            y, kc, vc = _attn_layer_decode(lp, cfg, carry, kc, vc, pos,
                                           window=window,
                                           mrope_positions=mrope_positions)
            return y, (kc, vc)
        x, (kc, vc) = jax.lax.scan(body, x, (params["layers"],
                                             cache["k"], cache["v"]))
        cache = {"k": kc, "v": vc}

    elif fam == "ssm":
        # single-token time/channel mix via the full-seq path with S=1
        def body(carry, xs):
            lp, wkv, x_tm, x_cm = xs
            st = {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}
            y, st = _rwkv_layer_fwd(lp, cfg, carry, st)
            return y, (st["wkv"], st["x_tm"], st["x_cm"])
        x, (wkv, x_tm, x_cm) = jax.lax.scan(
            body, x, (params["layers"], cache["wkv"], cache["x_tm"],
                      cache["x_cm"]))
        cache = {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}

    elif fam == "hybrid":
        uc = cache["units"]

        def unit_body(carry, xs):
            up, c = xs
            y = carry
            y, s1 = _rec_layer_decode(up["rec1"], cfg, y,
                                      {"h": c["rec1_h"], "conv": c["rec1_conv"]})
            y, s2 = _rec_layer_decode(up["rec2"], cfg, y,
                                      {"h": c["rec2_h"], "conv": c["rec2_conv"]})
            y, kc, vc = _attn_layer_decode(up["attn"], cfg, y, c["k"], c["v"],
                                           pos, window=cfg.local_window)
            out = {"rec1_h": s1["h"], "rec1_conv": s1["conv"],
                   "rec2_h": s2["h"], "rec2_conv": s2["conv"],
                   "k": kc, "v": vc}
            return y, out
        x, uc = jax.lax.scan(unit_body, x, (params["units"], uc))
        cache = dict(cache, units=uc)
        if "tail" in params:
            def tail_body(carry, xs):
                lp, h, conv = xs
                y, st = _rec_layer_decode(lp, cfg, carry,
                                          {"h": h, "conv": conv})
                return y, (st["h"], st["conv"])
            x, (th, tconv) = jax.lax.scan(
                tail_body, x, (params["tail"], cache["tail"]["h"],
                               cache["tail"]["conv"]))
            cache = dict(cache, tail={"h": th, "conv": tconv})

    elif fam == "encdec":
        def body(carry, xs):
            lp, sk, sv, ckv, cvv = xs
            y, sk, sv = _dec_layer_decode(lp, cfg, carry, sk, sv, ckv, cvv,
                                          pos, window=window)
            return y, (sk, sv)
        x, (sk, sv) = jax.lax.scan(
            body, x, (params["decoder"], cache["self_k"], cache["self_v"],
                      cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, self_k=sk, self_v=sv)
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
    return x @ params["unembed"], cache
