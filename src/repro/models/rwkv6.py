"""RWKV6 "Finch" block: time-mix with data-dependent decay + channel-mix.

State per layer:
  wkv:   (B, H, hd, hd)  matrix-valued attention state
  x_tm:  (B, d)          last input to time-mix (token shift)
  x_cm:  (B, d)          last input to channel-mix (token shift)

The sequential WKV recurrence is the compute hot-spot; ``repro.kernels.wkv6``
provides the Pallas TPU kernel, this module the pure-jnp semantics (also the
kernel's oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, init_groupnorm, groupnorm_heads

LORA_R = 32          # low-rank size for data-dependent token-shift mixing
DECAY_LORA_R = 64    # low-rank size for data-dependent decay

# WKV implementation: "chunked" (default — chunk-parallel, MXU-friendly,
# ~chunk× less state HBM traffic; see §Perf) or "scan" (paper-faithful
# per-token recurrence, also the numerics oracle).
WKV_IMPL = "chunked"

_MIX_NAMES = ("r", "k", "v", "w", "g")


def init_time_mix(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    ks = iter(jax.random.split(key, 16))
    p = {
        "mu": (jax.random.uniform(next(ks), (len(_MIX_NAMES), d)) * 0.5
               ).astype(jnp.float32),
        # data-dependent token shift (ddlerp) low-rank
        "ts_w1": dense_init(next(ks), d, LORA_R * len(_MIX_NAMES), dtype,
                            scale=1e-2),
        "ts_w2": (jax.random.normal(next(ks),
                                    (len(_MIX_NAMES), LORA_R, d)) * 1e-2
                  ).astype(dtype),
        "wr": dense_init(next(ks), d, d, dtype),
        "wk": dense_init(next(ks), d, d, dtype),
        "wv": dense_init(next(ks), d, d, dtype),
        "wg": dense_init(next(ks), d, d, dtype),
        "wo": dense_init(next(ks), d, d, dtype),
        # decay: w = exp(-exp(w0 + lora(x)))
        "decay_base": (jax.random.uniform(next(ks), (d,)) * -1.0 - 4.0
                       ).astype(jnp.float32),
        "decay_w1": dense_init(next(ks), d, DECAY_LORA_R, dtype, scale=1e-2),
        "decay_w2": dense_init(next(ks), DECAY_LORA_R, d, dtype, scale=1e-2),
        # per-channel "bonus" for the current token
        "u": (jax.random.uniform(next(ks), (H, hd)) * 0.5).astype(jnp.float32),
        "ln_x": init_groupnorm(H, hd, dtype),
    }
    return p


def init_channel_mix(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "mu_k": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(jnp.float32),
        "mu_r": (jax.random.uniform(ks[1], (d,)) * 0.5).astype(jnp.float32),
        "wk": dense_init(ks[2], d, cfg.d_ff, dtype),
        "wv": dense_init(ks[3], cfg.d_ff, d, dtype),
        "wr": dense_init(jax.random.fold_in(ks[3], 1), d, d, dtype),
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift interpolation -> per-target mixed inputs.

    x, x_prev: (B, S, d). Returns dict name -> (B, S, d).
    """
    dx = x_prev - x
    base = x + dx * p["mu"][None, None, 0]                  # coarse mix for lora in
    lora = jnp.tanh(base @ p["ts_w1"])                      # (B,S,R*5)
    B, S, _ = x.shape
    lora = lora.reshape(B, S, len(_MIX_NAMES), LORA_R)
    adj = jnp.einsum("bsnr,nrd->bsnd", lora, p["ts_w2"])    # (B,S,5,d)
    out = {}
    for i, name in enumerate(_MIX_NAMES):
        mu = p["mu"][i][None, None] + adj[:, :, i]
        out[name] = x + dx * mu.astype(x.dtype)
    return out


def wkv_scan(r, k, v, w, u, state):
    """Sequential WKV recurrence (pure-jnp oracle for the Pallas kernel).

    r,k,v: (B, S, H, hd); w: (B, S, H, hd) decay in (0,1);
    u: (H, hd) bonus; state: (B, H, hd, hd).
    Returns out (B, S, H, hd), new state.

      y_t = (S_t + (u ∘ k_t) ⊗ v_t)ᵀ r_t
      S_{t+1} = diag(w_t) S_t + k_t ⊗ v_t
    """
    B, S, H, hd = r.shape

    def step(s, inp):
        rt, kt, vt, wt = inp                                  # (B,H,hd)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)              # (B,H,hd,hd)
        eff = s + u[None, :, :, None] * kv
        yt = jnp.einsum("bhij,bhi->bhj", eff, rt)
        s = s * wt[..., None] + kv
        return s, yt

    xs = tuple(a.swapaxes(0, 1) for a in
               (r.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), w.astype(jnp.float32)))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), state                          # (B,S,H,hd)


def wkv_scan_chunked(r, k, v, w, u, state, *, chunk: int = 16):
    """Chunk-parallel WKV6 (beyond-paper §Perf optimization).

    Mathematically identical to ``wkv_scan`` but processes the sequence in
    chunks: within-chunk interactions become (C×C×hd) MXU matmuls and the
    (hd×hd) state is carried only once per chunk instead of once per token —
    cutting state HBM traffic by ~chunk× (the dominant roofline term of the
    XLA per-step scan) and replacing VPU elementwise chains with MXU work.

    Numerics: the k-side state scaling and the inter-chunk r scaling use
    exponents ≤ 0 (always safe). The intra-chunk pairwise factorization
    r·exp(cum_i) × k·exp(−cum_{j+1}) is only fp32-safe while the per-chunk
    cumulative |log w| stays ≤ ~40 — chunk=16 guarantees this for any decay
    w ≥ exp(−2.5) per step (far below RWKV6's operating range); harder decay
    saturates the 1e30 clamp, erring only on ~fully-decayed pairs.
    """
    B, S, H, hd = r.shape
    if S % chunk != 0 or S < 2 * chunk:
        return wkv_scan(r, k, v, w, u, state)
    NC, C = S // chunk, chunk
    f32 = jnp.float32

    def resh(x):
        return x.astype(f32).reshape(B, NC, C, H, hd).transpose(1, 0, 3, 2, 4)

    r_, k_, v_, w_ = map(resh, (r, k, v, w))        # (NC, B, H, C, hd)
    logw = jnp.log(jnp.maximum(w_, 1e-38))
    cum = jnp.cumsum(logw, axis=-2) - logw           # exclusive cumsum
    cum_total = cum[..., -1:, :] + logw[..., -1:, :]  # (NC,B,H,1,hd)

    # intra-chunk pairwise decay: exponent cum_i - (cum_j + logw_j) <= 0
    r_dec = r_ * jnp.exp(cum)                        # (NC,B,H,C,hd)
    k_dec = k_ * jnp.exp(-(cum + logw))
    # mask j < i; the exponent for j >= i is positive -> must mask BEFORE exp
    # to stay safe we compute A via masked matmul of decayed forms (exponent
    # <= 0 whenever j < i, so overflow cannot occur on kept entries; masked
    # entries may overflow harmlessly -> clamp)
    k_dec = jnp.clip(k_dec, -1e30, 1e30)
    A = jnp.einsum("nbhid,nbhjd->nbhij", r_dec, k_dec)
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
    A = jnp.where(mask[None, None, None], A, 0.0)
    y_intra = jnp.einsum("nbhij,nbhjd->nbhid", A, v_)
    # current-token bonus u
    bonus = jnp.sum(r_ * u[None, None, :, None, :] * k_, axis=-1)
    y_intra = y_intra + bonus[..., None] * v_

    # inter-chunk: scan over chunks carrying the (hd,hd) state
    k_state = k_ * jnp.exp(jnp.clip(cum_total - cum - logw, -60.0, 60.0))

    def step(s, inp):
        rd, ks, vv, ct = inp
        y = jnp.einsum("bhid,bhde->bhie", rd, s)     # (B,H,C,hd_v)
        s = s * jnp.exp(ct[..., 0, :])[..., None] \
            + jnp.einsum("bhjd,bhje->bhde", ks, vv)
        return s, y

    state, y_inter = jax.lax.scan(
        step, state.astype(f32), (r_dec, k_state, v_, cum_total))
    y = (y_intra + y_inter).transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return y, state


def time_mix(p, cfg: ModelConfig, x, x_prev, state):
    """x: (B,S,d); x_prev: (B,d) last token of previous chunk; state wkv.
    Returns (out, new_x_prev, new_state)."""
    B, S, d = x.shape
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mixed = _ddlerp(p, x, shifted)

    r = (mixed["r"] @ p["wr"]).reshape(B, S, H, hd)
    k = (mixed["k"] @ p["wk"]).reshape(B, S, H, hd)
    v = (mixed["v"] @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(mixed["g"] @ p["wg"])
    w = jnp.exp(-jnp.exp(
        p["decay_base"][None, None].astype(jnp.float32)
        + (jnp.tanh(mixed["w"] @ p["decay_w1"]) @ p["decay_w2"]
           ).astype(jnp.float32)))                           # (B,S,d) in (0,1)
    w = w.reshape(B, S, H, hd)

    if WKV_IMPL == "chunked" and S >= 32:
        out, state = wkv_scan_chunked(r, k, v, w,
                                      p["u"].astype(jnp.float32), state)
    else:
        out, state = wkv_scan(r, k, v, w, p["u"].astype(jnp.float32), state)
    out = groupnorm_heads(p["ln_x"], out).reshape(B, S, d).astype(x.dtype)
    out = (out * g) @ p["wo"]
    return out, x[:, -1], state


def channel_mix(p, x, x_prev):
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    dx = shifted - x
    xk = x + dx * p["mu_k"][None, None].astype(x.dtype)
    xr = x + dx * p["mu_r"][None, None].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1]
