"""Partition specs for params, caches and batches.

Strategy (baseline — §Perf iterates on this):
  * weights: FSDP over ``data`` on the non-parallel dim × tensor-parallel over
    ``model`` on the parallel dim (heads / d_ff / vocab)
  * MoE experts: expert-parallel over ``model`` when num_experts divides the
    axis (dbrx), else tensor-parallel d_ff sharding (grok)
  * batch dims: sharded over (``pod``, ``data``) when divisible
  * decode KV caches: batch→data-ish, kv-heads→model when divisible else
    head_dim→model (contraction sharding), else replicated
  * a dim is sharded only if divisible by the axis size — otherwise None

All rules are name/shape based so they apply uniformly to every family.
"""
from __future__ import annotations

from typing import Dict

import jax
from jax.sharding import PartitionSpec as P

# leading-axis stacked containers (scan-over-layers)
_STACK_KEYS = {"layers", "units", "tail", "encoder", "decoder"}
# 2D weights whose *input* dim is the parallel one
_REVERSED = {"wo", "w_down", "w_out"}
_MOE_W = {"w_up", "w_gate", "w_down"}


def _axis(n: int, size: int, name):
    return name if (size > 1 and n % size == 0) else None


def _base_spec(path_names, name: str, shape, axes: Dict[str, int]):
    """PartitionSpec for an *unstacked* leaf."""
    dm, dd = axes.get("model", 1), axes.get("data", 1)
    nd = len(shape)
    if name == "embed":
        return P(_axis(shape[0], dm, "model"), _axis(shape[1], dd, "data"))
    if name == "unembed":
        return P(_axis(shape[0], dd, "data"), _axis(shape[1], dm, "model"))
    if name == "router":
        return P(_axis(shape[0], dd, "data"), None)
    if "moe" in path_names and name in _MOE_W and nd == 3:
        E = shape[0]
        ep = E % dm == 0 and dm > 1
        if name == "w_down":
            if ep:
                return P("model", None, _axis(shape[2], dd, "data"))
            return P(None, _axis(shape[1], dm, "model"),
                     _axis(shape[2], dd, "data"))
        if ep:
            return P("model", _axis(shape[1], dd, "data"), None)
        return P(None, _axis(shape[1], dd, "data"),
                 _axis(shape[2], dm, "model"))
    if name == "conv_w":
        return P(None, _axis(shape[1], dm, "model"))
    if name == "ts_w2":
        return P(None, None, _axis(shape[2], dd, "data"))
    if nd == 2:
        if name in _REVERSED:
            return P(_axis(shape[0], dm, "model"), _axis(shape[1], dd, "data"))
        return P(_axis(shape[0], dd, "data"), _axis(shape[1], dm, "model"))
    return P(*([None] * nd))  # 1D scales/biases etc: replicated


def param_pspecs(params_tree, axes: Dict[str, int]):
    """Map a params pytree (arrays or ShapeDtypeStructs) -> PartitionSpecs."""

    def rule(path, leaf):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1]
        shape = leaf.shape
        stacked = any(n in _STACK_KEYS for n in names[:-1])
        if stacked:
            base = _base_spec(names, name, shape[1:], axes)
            return P(*((None,) + tuple(base)))
        return _base_spec(names, name, shape, axes)

    return jax.tree_util.tree_map_with_path(rule, params_tree)


# --------------------------------------------------------------------------- #
# batch / cache specs
# --------------------------------------------------------------------------- #

def batch_axes(B: int, axes: Dict[str, int]):
    """Largest (pod?,data?) combination that divides the batch."""
    names = []
    size = 1
    for a in ("pod", "data"):
        if a in axes:
            names.append(a)
            size *= axes[a]
    while names and B % size != 0:
        a = names.pop(0)
        size //= axes[a]
    if not names:
        return None
    return tuple(names) if len(names) > 1 else names[0]


def batch_pspecs(batch_tree, axes: Dict[str, int]):
    def rule(path, leaf):
        b = batch_axes(leaf.shape[0], axes)
        return P(*((b,) + (None,) * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_pspecs(cache_tree, axes: Dict[str, int]):
    """Decode/prefill cache shardings. Leaves are (L, B, ...) stacked."""
    dm, dd = axes.get("model", 1), axes.get("data", 1)

    def rule(path, leaf):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1]
        s = leaf.shape
        B = s[1]
        ba = batch_axes(B, axes)
        if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            # (L, B, W, KV, hd): batch->data; kv-heads->model when divisible,
            # else sequence->model (measured 19x lower collective bytes than
            # head_dim->model, which triggers involuntary SPMD remat).
            kv = _axis(s[3], dm, "model")
            if ba is None:
                # B=1 (long-context): shard sequence over everything possible
                both = dd * dm
                if kv is None and s[2] % both == 0 and dd > 1:
                    return P(None, None, ("data", "model"), None, None)
                return P(None, None, _axis(s[2], dd, "data"), kv, None)
            seq = None if kv else _axis(s[2], dm, "model")
            return P(None, ba, seq, kv, None)
        if name == "wkv":                       # (L, B, H, hd, hd)
            return P(None, ba, _axis(s[2], dm, "model"), None, None)
        if name in ("x_tm", "x_cm"):            # (L, B, d)
            return P(None, ba, _axis(s[2], dm, "model"))
        if name in ("rec1_h", "rec2_h", "h"):   # (U, B, dr)
            return P(None, ba, _axis(s[2], dm, "model"))
        if name in ("rec1_conv", "rec2_conv", "conv"):  # (U, B, cw-1, dr)
            return P(None, ba, None, _axis(s[3], dm, "model"))
        return P(*([None] * len(s)))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def drop_axis(tree_pspecs, axis: str):
    """Remove one mesh axis from every spec (e.g. drop FSDP for decode)."""
    def fn(ps):
        def strip(a):
            if a == axis:
                return None
            if isinstance(a, tuple):
                rest = tuple(x for x in a if x != axis)
                return rest if len(rest) > 1 else (rest[0] if rest else None)
            return a
        return P(*[strip(a) for a in ps])
    return jax.tree.map(fn, tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def to_named(tree_pspecs, mesh):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
