"""Shared model components: norms, rotary embeddings (incl. M-RoPE), MLPs,
GQA attention (full / sliding-window / query-chunked / decode-with-cache).

Everything is functional: params are pytrees of jnp arrays, built by
``init_*`` helpers, consumed by pure apply functions.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG_INF = -1e30          # finite mask value (avoids NaN from -inf softmax rows)
Q_CHUNK = 1024           # query-chunk size for long-sequence attention


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #

def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


def init_groupnorm(heads: int, hd: int, dtype):
    return {"scale": jnp.ones((heads, hd), dtype),
            "bias": jnp.zeros((heads, hd), dtype)}


def groupnorm_heads(params, x, eps: float = 64e-5):
    """LayerNorm per head — x: (..., H, hd). Used by RWKV6."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


# --------------------------------------------------------------------------- #
# rotary embeddings
# --------------------------------------------------------------------------- #

def _rope_angles(positions, half: int, theta: float):
    """positions: (...,) -> (..., half) angles."""
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    ang = _rope_angles(positions, half, theta)               # (S, half) or (B,S,half)
    if ang.ndim == 2:
        ang = ang[None]                                      # (1, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if 2 * half < hd:                                        # odd head_dim tail
        rot = jnp.concatenate([rot, x[..., 2 * half:]], axis=-1)
    return rot.astype(x.dtype)


def mrope_sections(half: int):
    """Split of rotary pair-dims among (temporal, height, width) sections."""
    s1 = half // 4
    s2 = (half - s1) // 2
    return (s1, s2, half - s1 - s2)


def apply_mrope(x, positions, theta: float = 10_000.0):
    """Qwen2-VL multimodal RoPE. x: (B,S,H,hd); positions: (B,S,3) int."""
    hd = x.shape[-1]
    half = hd // 2
    secs = mrope_sections(half)
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    # per-pair position id chosen by section
    sec_id = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                              for i, s in enumerate(secs)])  # (half,)
    p = positions.astype(jnp.float32)                         # (B,S,3)
    pos_per_pair = p[..., sec_id]                             # (B,S,half)
    ang = pos_per_pair * freqs[None, None, :]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rot.astype(x.dtype)


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #

def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    d, dff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, dff, dtype),
         "w_down": dense_init(ks[1], dff, d, dtype)}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[2], d, dff, dtype)
    return p


def mlp(params, x, cfg: ModelConfig):
    act = activation_fn(cfg.activation)
    h = act(x @ params["w_up"])
    if cfg.gated_mlp:
        h = h * (x @ params["w_gate"])
    return h @ params["w_down"]


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #

def init_attention(key, cfg: ModelConfig, dtype, num_heads=None, num_kv=None,
                   head_dim=None):
    H = num_heads or cfg.num_heads
    KV = num_kv or cfg.num_kv_heads
    hd = head_dim or cfg.head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, KV * hd, dtype),
        "wv": dense_init(ks[2], d, KV * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype, scale=1.0 / math.sqrt(H * hd)),
    }


def _attend(q, k, v, qpos, kpos, *, window=None, causal=True):
    """Grouped-query attention core.

    q: (B, Sq, KV, G, hd)  k,v: (B, Sk, KV, hd)
    qpos: (Sq,) absolute query positions; kpos: (Sk,) key positions
    (kpos < 0 means empty slot).
    """
    hd = q.shape[-1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32)
    s *= hd ** -0.5
    mask = kpos[None, :] >= 0
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v)


def attention(q, k, v, *, q_offset=0, window=None, causal=True,
              chunk=Q_CHUNK):
    """Full-sequence attention with query chunking for long S.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Returns (B, Sq, H, hd).
    q_offset: absolute position of q[0] (cached-prefix prefill).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    kpos = jnp.arange(Sk)

    if Sq <= chunk or Sq % chunk != 0:
        qpos = q_offset + jnp.arange(Sq)
        out = _attend(qg, k, v, qpos, kpos, window=window, causal=causal)
        return out.reshape(B, Sq, H, hd)

    nq = Sq // chunk
    qc = qg.reshape(B, nq, chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qpos = (q_offset + jnp.arange(Sq)).reshape(nq, chunk)

    def body(args):
        qi, pi = args
        return _attend(qi, k, v, pi, kpos, window=window, causal=causal)

    out = jax.lax.map(body, (qc, qpos))                       # (nq,B,chunk,KV,G,hd)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)


def decode_attend(q, k_cache, v_cache, kpos, pos, *, window=None):
    """Single-token decode attention against a (ring or linear) KV cache.

    q: (B, 1, H, hd); caches: (B, W, KV, hd); kpos: (W,) slot->abs position
    (-1 empty); pos: scalar current position.
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32)
    s *= hd ** -0.5
    mask = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        mask &= kpos > pos - window
    s = jnp.where(mask[None, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)
    return out.reshape(B, 1, H, hd)
