"""Griffin / RecurrentGemma recurrent block: conv1d + RG-LRU gated linear
recurrence, plus the local-attention block used in the 2:1 hybrid pattern.

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    a_t = exp(-c · softplus(Λ) · r_t)     per-channel decay, c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training uses an associative scan (parallel, sub-quadratic); decode is a
single-step update. ``repro.kernels.rglru`` holds the Pallas TPU kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

RG_C = 8.0


def init_rglru_block(key, cfg: ModelConfig, dtype):
    d, dr = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 8)
    return {
        "w_in_y": dense_init(ks[0], d, dr, dtype),     # recurrent branch in
        "w_in_gate": dense_init(ks[1], d, dr, dtype),  # gelu gate branch
        "w_out": dense_init(ks[2], dr, d, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, dr))
                   * (cfg.conv_width ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "wa": dense_init(ks[4], dr, dr, dtype, scale=1e-2),
        "ba": jnp.zeros((dr,), jnp.float32),
        "wx": dense_init(ks[5], dr, dr, dtype, scale=1e-2),
        "bx": jnp.zeros((dr,), jnp.float32),
        # Λ init so that a ∈ (0.9, 0.999) at r=1 (per Griffin paper)
        "lam": jax.random.uniform(ks[6], (dr,), jnp.float32,
                                  minval=0.0013, maxval=0.1320),
    }


def _causal_conv(p, x, x_hist):
    """Depthwise causal conv1d, width cfg.conv_width.
    x: (B,S,dr); x_hist: (B, width-1, dr) previous inputs."""
    w = p["conv_w"]                                    # (W, dr)
    W = w.shape[0]
    xfull = jnp.concatenate([x_hist.astype(x.dtype), x], axis=1)
    out = sum(xfull[:, i:i + x.shape[1]] * w[i][None, None]
              for i in range(W))
    new_hist = xfull[:, x.shape[1]:]                   # last W-1 inputs
    return out + p["conv_b"][None, None], new_hist


def _rglru_coeffs(p, x):
    """x: (..., dr) -> decay a and scaled input (both fp32)."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(x32 @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -RG_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * i * x32
    return a, gated


def rglru_scan(p, x, h0):
    """Associative-scan linear recurrence. x: (B,S,dr); h0: (B,dr)."""
    a, b = _rglru_coeffs(p, x)                         # (B,S,dr) fp32

    # h_t = a_t h_{t-1} + b_t; fold h0 into first step
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh.astype(x.dtype), hh[:, -1]


def rglru_step(p, x_t, h):
    """Decode step. x_t: (B,dr); h: (B,dr)."""
    a, b = _rglru_coeffs(p, x_t)
    h = a * h.astype(jnp.float32) + b
    return h.astype(x_t.dtype), h


def rglru_block(p, x, state):
    """Full-seq recurrent block. x: (B,S,d);
    state: {"h": (B,dr), "conv": (B,W-1,dr)}."""
    gate = jax.nn.gelu(x @ p["w_in_gate"])
    y = x @ p["w_in_y"]
    y, conv_hist = _causal_conv(p, y, state["conv"])
    y, h = rglru_scan(p, y, state["h"])
    out = (y * gate) @ p["w_out"]
    return out, {"h": h, "conv": conv_hist}


def rglru_block_step(p, x_t, state):
    """Decode step. x_t: (B,d)."""
    gate = jax.nn.gelu(x_t @ p["w_in_gate"])
    y = x_t @ p["w_in_y"]
    # conv via history buffer
    w = p["conv_w"]
    W = w.shape[0]
    hist = state["conv"]                               # (B, W-1, dr)
    xfull = jnp.concatenate([hist.astype(y.dtype), y[:, None]], axis=1)
    y = jnp.einsum("bwd,wd->bd", xfull, w) + p["conv_b"][None]
    new_hist = xfull[:, 1:]
    y, h = rglru_step(p, y, state["h"])
    out = (y * gate) @ p["w_out"]
    return out, {"h": h, "conv": new_hist}


def init_recurrent_state(cfg: ModelConfig, batch: int, dtype):
    return {"h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width),
                              dtype)}
