"""Generate the §Dry-run / §Roofline / §Perf markdown tables for
EXPERIMENTS.md from experiments/*.json.

    PYTHONPATH=src python experiments/make_report.py > experiments/report.md
"""
import json
import os

HERE = os.path.dirname(__file__)


def load(name):
    p = os.path.join(HERE, name)
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def fmt(x):
    return f"{x:.3g}" if isinstance(x, float) else str(x)


def dryrun_tables():
    recs = [r for r in load("dryrun.json") if "error" not in r]
    single = sorted([r for r in recs if r["mesh"] == "single"],
                    key=lambda r: (r["arch"], r["shape"]))
    multi = [r for r in recs if r["mesh"] == "multi"]
    print(f"### §Dry-run summary\n")
    print(f"- single-pod (16×16 = 256 chips): **{len(single)}/40** "
          f"(arch × shape) lower + compile OK")
    print(f"- multi-pod (2×16×16 = 512 chips): **{len(multi)}/40** OK — "
          f"the `pod` axis shards\n")
    print("| arch | shape | compile_s | temp GB/dev | args GB/dev | "
          "collectives (count) |")
    print("|---|---|---|---|---|---|")
    for r in single:
        cc = r.get("collective_counts", {})
        ccs = " ".join(f"{k.split('-')[1] if '-' in k else k}:{v}"
                       for k, v in sorted(cc.items()))
        tmp = r.get("temp_size_in_bytes", 0) / 1e9
        arg = r.get("argument_size_in_bytes", 0) / 1e9
        print(f"| {r['arch']} | {r['shape']} | {r['compile_s']} | "
              f"{tmp:.2f} | {arg:.2f} | {ccs} |")
    print()

    print("### §Roofline (single-pod baselines, per-device terms)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| MODEL_FLOPS/HLO | note |")
    print("|---|---|---|---|---|---|---|---|")
    for r in single:
        note = ""
        if r["shape"] == "long_500k" and r["arch"] not in (
                "rwkv6-1.6b", "recurrentgemma-2b", "h2o-danube-1.8b"):
            note = "SWA long-context variant"
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
              f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
              f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | {note} |")
    print()


def hillclimb_table():
    recs = load("hillclimb.json")
    if not recs:
        return
    print("### §Perf iteration measurements\n")
    print("| pair | variant | compute_s | memory_s | collective_s | "
          "dominant |")
    print("|---|---|---|---|---|---|")
    for r in recs:
        print(f"| {r['pair']} | {r['variant']} | {r['compute_s']:.3e} | "
              f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
              f"{r['dominant']} |")
    print()


def bench_summary():
    rows = load("results/fig12_carbon_slo.json")
    if not rows:
        return
    rows = rows["rows"]
    print("### Main-evaluation summary (Fig 12)\n")
    print("| model | task | grid | mode | carbon g/req | SLO | cache TB |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['model']} | {r['task']} | {r['grid']} | {r['mode']} | "
              f"{r['carbon_per_req_g']:.4f} | {r['slo']:.3f} | "
              f"{r['avg_cache_tb']:.1f} |")
    print()


if __name__ == "__main__":
    dryrun_tables()
    hillclimb_table()
    bench_summary()
