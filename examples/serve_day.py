"""End-to-end serving driver: a 24-hour GreenCache day in the FR grid —
profiles the task, forecasts load/CI hourly, solves the ILP, resizes the
cache, and reports carbon vs the Full-Cache and No-Cache baselines
(paper Figs 12-14).

    PYTHONPATH=src python examples/serve_day.py [--grid FR] [--task conversation]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", default="FR")
    ap.add_argument("--task", default="conversation")
    ap.add_argument("--plan", nargs="+", default=None,
                    help="resource plan spec(s), e.g. "
                         "'cache=auto fleet=l40:2' or 'cache=auto "
                         "prefill=h100:1 decode=a100:2'; several specs "
                         "let the solver co-decide the plan hourly")
    a = ap.parse_args()
    results = {}
    for mode in ["none", "full", "greencache"]:
        print(f"\n### mode={mode}")
        argv = ["--model", "llama3-70b", "--task", a.task, "--grid", a.grid,
                "--mode", mode, "--warmup", "10000"]
        if a.plan:
            argv += ["--plan", *a.plan]
        results[mode] = serve_main(argv)
    gc, fc = results["greencache"], results["full"]
    red = 1 - gc.carbon_per_request_g / fc.carbon_per_request_g
    print(f"\nGreenCache vs Full-Cache: {red * 100:.1f}% carbon reduction "
          f"at {gc.slo_attainment * 100:.1f}% SLO attainment "
          f"(paper: 15.1% avg in FR, >90% SLO)")
