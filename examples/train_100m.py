"""End-to-end training driver: a ~100M-parameter dense model trained for a
few hundred steps on CPU with the full substrate (data pipeline, AdamW,
remat scan, chunked CE loss, checkpointing).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

(For the multi-pod production shapes, the identical train_step is lowered
and compiled by ``python -m repro.launch.dryrun`` on the (2,16,16) mesh.)
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="yi-6b")
    a = ap.parse_args()
    # ~100M params: 12 layers × d_model 768 (+ embeddings)
    losses = train_main([
        "--arch", a.arch, "--reduced", "--layers", "12",
        "--d-model", "768", "--batch", "4", "--seq", "256",
        "--steps", str(a.steps), "--checkpoint", "/tmp/repro_100m_ckpt"])
    assert losses[-1] < losses[0], "loss should decrease"
    print("OK: loss decreased", losses[0], "->", losses[-1])
