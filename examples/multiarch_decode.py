"""Per-architecture serving demo: run real prefill+decode with context-cache
reuse for every assigned architecture family (reduced configs, CPU), showing
the paper's mechanism is family-agnostic: KV-prefix reuse for attention
archs, state-snapshot reuse for recurrent archs.

    PYTHONPATH=src python examples/multiarch_decode.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.kvstore import KVStore
from repro.core.policies import POLICIES
from repro.models.transformer import init_params
from repro.serving.realexec import RealExecutionEngine

ARCHS = ["yi-6b", "h2o-danube-1.8b", "dbrx-132b", "rwkv6-1.6b",
         "recurrentgemma-2b", "qwen2-vl-2b"]

for arch in ARCHS:
    cfg = get_config(arch)
    nl = 4 if cfg.family == "hybrid" else 2
    cfg = cfg.reduced(num_layers=nl, d_model=128)
    if cfg.family in ("encdec", "vlm"):
        # realexec demo uses the token path; modality stubs are exercised in
        # tests/benchmarks — skip here for brevity
        if cfg.family == "encdec":
            continue
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    store = KVStore(64e6, POLICIES["lcs"],
                    max(cfg.kv_bytes_per_token, 1.0))
    if cfg.family == "vlm":
        # decode-only demo for the VLM text path
        pass
    eng = RealExecutionEngine(cfg, params, store, max_len=128)
    rng = np.random.default_rng(1)
    ctx = [int(t) for t in rng.integers(0, cfg.vocab_size, 20)]
    t0 = time.time()
    r1 = eng.generate(f"{arch}-c0", ctx, num_new=3)
    ctx2 = ctx + r1.tokens + [int(t) for t in rng.integers(0, cfg.vocab_size, 6)]
    r2 = eng.generate(f"{arch}-c0", ctx2, num_new=3)
    kind = "state-snapshot" if cfg.family in ("ssm", "hybrid") else "KV-prefix"
    print(f"{arch:22s} [{cfg.family:6s}] {kind:14s} reuse: "
          f"turn2 computed {r2.prefill_tokens_computed:2d}/{len(ctx2)} tokens "
          f"(reused {r2.reused_tokens}) in {time.time()-t0:.1f}s")
print("\nAll families serve with context-cache reuse.")
