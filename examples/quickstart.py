"""Quickstart: GreenCache in 60 seconds.

1. Real KV-prefix caching with an actual JAX model (reduced yi-6b):
   cache hit -> only the uncached suffix is prefilled.
2. The carbon tradeoff: when is caching green?
3. One carbon-aware sizing decision with the ILP solver.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.carbon import CarbonModel, GRID_CI
from repro.core.kvstore import KVStore
from repro.core.policies import POLICIES
from repro.models.transformer import init_params
from repro.serving.realexec import RealExecutionEngine

print("=" * 70)
print("1) Real KV-prefix caching (reduced yi-6b, CPU)")
print("=" * 70)
cfg = get_config("yi-6b").reduced(num_layers=2, d_model=128)
params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
store = KVStore(64e6, POLICIES["lcs_chat"], cfg.kv_bytes_per_token)
eng = RealExecutionEngine(cfg, params, store, max_len=128)

rng = np.random.default_rng(0)
turn1 = [int(t) for t in rng.integers(0, cfg.vocab_size, 24)]
r1 = eng.generate("conv-demo", turn1, num_new=4)
print(f"turn 1: prefilled {r1.prefill_tokens_computed} tokens "
      f"(cache miss), generated {r1.tokens}")
turn2 = turn1 + r1.tokens + [int(t) for t in rng.integers(0, cfg.vocab_size, 8)]
r2 = eng.generate("conv-demo", turn2, num_new=4)
print(f"turn 2: prefilled {r2.prefill_tokens_computed} tokens, "
      f"REUSED {r2.reused_tokens} cached tokens, generated {r2.tokens}")

print()
print("=" * 70)
print("2) The carbon tradeoff (paper Eq. 5): cache 16 TB for one request")
print("=" * 70)
cm = CarbonModel()
e_nc, e_c = 3.1e-4, 2.8e-4        # kWh/request, no-cache vs cached (profiled)
for grid in ["FR", "ES", "MISO"]:
    ci = GRID_CI[grid]
    nc = cm.total_g(e_nc, ci, 0.0, 0.67)
    c = cm.total_g(e_c, ci, 16.0, 0.67)
    verdict = "cache is GREEN" if c < nc else "cache EMITS MORE"
    print(f"  {grid:5s} (CI={ci:3.0f}): no-cache {nc:.4f} g, "
          f"16TB-cache {c:.4f} g -> {verdict}")

print()
print("=" * 70)
print("3) One ILP sizing decision (profiled llama3-70B, chat)")
print("=" * 70)
from repro.core.profiler import run_profiler
from repro.core.solver import solve_cache_schedule
from repro.serving.perfmodel import SERVING_MODELS, SLOS
from repro.workloads.conversations import ConversationWorkload

m = SERVING_MODELS["llama3-70b"]
prof = run_profiler(m, "conversation", lambda s: ConversationWorkload(seed=s),
                    cm, rates=[0.4, 1.0, 1.6], sizes_tb=[0, 2, 8, 16],
                    warmup_prompts=6000, meas_seconds=500)
rates = [0.3, 0.4, 0.9, 1.5, 1.6, 1.2]          # predicted next 6 hours
for grid in ["FR", "CISO"]:
    cis = [GRID_CI[grid]] * 6
    res = solve_cache_schedule(prof, rates, cis, SLOS[("llama3-70b", "chat")],
                               cm)
    print(f"  {grid:5s}: hourly cache sizes {res.sizes_tb} TB "
          f"(solver={res.solver}, {res.solve_time_s:.2f}s)")
print("\nDone. See repro.launch.serve for the full 24-hour evaluation.")
