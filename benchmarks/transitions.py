"""Transition-aware reconfiguration vs the instant-switch solver on a
volatile-CI day (no direct paper figure; EcoServe 2502.05043 and
GreenLLM 2412.20322 motivate pricing the reconfiguration itself).

Scenario: the clean-but-volatile FR grid under a storm-shaped CI trace
(hour-to-hour multiplicative swings on top of the diurnal FR shape).
The solver co-decides (cache, fleet) hourly over {a100, h100} mixes
whose carbon ranking flips with CI — already-amortized a100 capacity
wins clean hours on embodied carbon, efficient h100 capacity wins dirty
hours on operational carbon — so a solver that believes reconfiguration
is free flaps between fleets whenever the forecast wiggles.  Both days
run the *same* engine with realistic transition costs
(``TransitionConfig``: per-type boot latency, drain accounting); the
only difference is whether the solver prices the switch:

  * ``instant`` — the PR-3 solver (``transition_aware_solver=False``):
    picks each hour's best option as if switching were free, then pays
    boot/drain energy and warmup-degraded SLO in the engine anyway.
  * ``aware``   — the transition-aware DP: switching carbon between
    consecutive hours plus a ``MIN_DWELL_H`` shape dwell, so the
    schedule exhibits hysteresis.

Derived row 1: the aware day must cut plan churn and total gCO2e at
equal (±0.5 pt) SLO attainment.

Derived row 2 is the regression anchor: a zero-cost transition config
(``TransitionConfig.free()``: boot latency 0, free migration, no drain,
``min_dwell=1``) must bit-reproduce the legacy instant-and-free
(``transitions=None``) hour records — carbon, cache sizes, SLO, hit
rates and hourly plans all equal.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import CarbonModel
from repro.core.controller import GreenCacheController
from repro.core.plan import ResourcePlan, TransitionConfig
from repro.core.profiler import run_profiler
from repro.serving.perfmodel import SERVING_MODELS

from benchmarks.common import (SMOKE, cap_requests, clip_day,
                               profiler_kwargs, save_result)

MODEL = "llama3-70b"
TASK = "conversation"
GRID = "FR"
PEAK_RATE = 1.1                     # req/s per reference-capacity unit
RATES = [0.2, 0.45, 0.7, 0.9, 1.2]  # per capacity unit
SIZES = [0, 4, 8, 16]
MIN_DWELL_H = 3
EPS_SLO = 0.005                     # ±0.5 pt attainment band

# candidate fleets: near-tied capacity, opposite carbon structure
FLEETS = ["a100:2", "h100:1", "a100:1,h100:1", "a100:3", "h100:2"]
SCALE = 4.8                         # widest candidate (h100:2) capacity

_CACHE = {}


def _workload(seed, scale=SCALE):
    from repro.workloads.conversations import ConversationWorkload
    return ConversationWorkload(seed=seed, load_scale=scale)


def volatile_ci(seed: int = 4) -> np.ndarray:
    """FR's diurnal CI shape under storm volatility: multiplicative
    hour-to-hour swings (wind ramps / dirty interconnect imports, mean
    factor ~1.8 — a stressed week, not the FR average) that repeatedly
    cross the a100-vs-h100 carbon break-even."""
    from repro.workloads.traces import ci_trace
    base = ci_trace(GRID, seed=seed)
    rng = np.random.default_rng(seed + 17)
    swing = rng.uniform(0.35, 3.2, size=len(base))
    return base * swing


def _profile():
    if "p" not in _CACHE:
        _CACHE["p"] = run_profiler(
            SERVING_MODELS[MODEL], TASK, _workload, CarbonModel(),
            rates=RATES[:2] if SMOKE else RATES,
            sizes_tb=SIZES[:2] if SMOKE else SIZES,
            warmup_prompts=cap_requests(8000, 400),
            policy="lcs_chat", **profiler_kwargs())
    return _CACHE["p"]


def _day(transitions, *, aware: bool = True, min_dwell: int = 1,
         seed: int = 11):
    from repro.workloads.traces import azure_rate_trace

    ctl = GreenCacheController(
        SERVING_MODELS[MODEL], _profile(), CarbonModel(), TASK,
        mode="greencache", policy="lcs_chat",
        plans=[ResourcePlan.single(None, fleet=f) for f in FLEETS],
        warm_requests=cap_requests(8000, 400), seed=seed,
        max_requests_per_hour=cap_requests(900),
        sizes_tb=SIZES[:2] if SMOKE else SIZES, rho_margin=0.0,
        transitions=transitions, min_dwell_hours=min_dwell,
        transition_aware_solver=aware)
    rate_trace, cis = clip_day(azure_rate_trace(PEAK_RATE * SCALE, seed=3),
                               volatile_ci())
    return ctl.run_day(_workload, rate_trace, cis)


def _row(name, res):
    return (f"transitions/{GRID}/{name}/total_g", res.total_carbon_g,
            f"slo={res.slo_attainment:.3f} changes={res.plan_changes} "
            f"transition_g={res.total_transition_g:.1f}")


def _same_records(a, b) -> bool:
    return len(a.hours) == len(b.hours) and all(
        ha.carbon_g == hb.carbon_g and ha.cache_tb == hb.cache_tb
        and ha.slo_frac == hb.slo_frac and ha.hit_rate == hb.hit_rate
        and ha.plan == hb.plan for ha, hb in zip(a.hours, b.hours))


def run():
    out = []
    cfg = TransitionConfig()
    seeds = [11] if SMOKE else [11, 23]
    payload = {"seeds": {}}
    wins = []
    for seed in seeds:
        instant = _day(cfg, aware=False, min_dwell=1, seed=seed)
        aware = _day(cfg, aware=True, min_dwell=MIN_DWELL_H, seed=seed)
        out.append(_row(f"seed{seed}/instant", instant))
        out.append(_row(f"seed{seed}/aware", aware))
        # when the instant solver never switches (possible on the tiny
        # smoke trace) there is no churn to suppress — count as a
        # non-loss rather than demanding a strict carbon win
        wins.append(aware.slo_attainment
                    >= instant.slo_attainment - EPS_SLO
                    and aware.plan_changes <= instant.plan_changes
                    and (aware.total_carbon_g < instant.total_carbon_g
                         or instant.plan_changes == 0))
        payload["seeds"][seed] = {
            k: {"total_g": r.total_carbon_g, "slo": r.slo_attainment,
                "plan_changes": r.plan_changes,
                "transition_g": r.total_transition_g,
                "hourly_plans": [h.plan for h in r.hours],
                "hourly_transitions": [h.transition for h in r.hours]}
            for k, r in [("instant", instant), ("aware", aware)]}
    beats = all(wins)
    out.append((f"transitions/{GRID}/aware_beats_instant", float(beats),
                f"lower gCO2e + fewer switches at >= equal SLO on "
                f"{len(wins)}/{len(wins)} seed(s)"))

    legacy = _day(None, aware=False, min_dwell=1)
    free = _day(TransitionConfig.free(), aware=True, min_dwell=1)
    repro_ok = _same_records(legacy, free)
    out.append(("transitions/zero_cost_bit_reproduces_legacy",
                float(repro_ok),
                "TransitionConfig.free() hour records == transitions=None"))

    payload["aware_beats_instant"] = bool(beats)
    payload["zero_cost_bit_repro"] = repro_ok
    save_result("transitions", payload)
    return out
