"""Fig 16: constraint-solver execution time per resize decision.
Paper: 7.03 s average with CBC on their instance sizes; ours is smaller
(17 sizes × 24 h) — we report both CBC and the exact-DP fallback."""
from __future__ import annotations

import numpy as np

from repro.core.solver import solve_cache_schedule
from repro.serving.perfmodel import SLOS

from benchmarks.common import SMOKE, CARBON, get_profile, save_result


def run():
    prof = get_profile("llama3-70b", "conversation")
    slo = SLOS[("llama3-70b", "chat")]
    rng = np.random.default_rng(0)
    times = {"cbc": [], "dp": []}
    objs = {"cbc": [], "dp": []}
    for trial in range(2 if SMOKE else 10):
        rates = rng.uniform(0.2, 1.6, 24)
        cis = rng.uniform(30, 300, 24)
        for use_ilp, name in [(True, "cbc"), (False, "dp")]:
            r = solve_cache_schedule(prof, rates, cis, slo, CARBON,
                                     use_ilp=use_ilp)
            times[name].append(r.solve_time_s)
            objs[name].append(r.objective_g)
    save_result("fig16_solver_overhead", {
        "cbc_times_s": times["cbc"], "dp_times_s": times["dp"]})
    return [
        ("fig16/cbc_avg_solve_s", float(np.mean(times["cbc"])),
         "paper: 7.03s on larger instance"),
        ("fig16/dp_avg_solve_s", float(np.mean(times["dp"])),
         "exact DP fallback"),
        ("fig16/dp_obj_within_5pct_of_cbc",
         float(np.mean([abs(a - b) / max(a, 1e-9) < 0.05
                        for a, b in zip(objs["cbc"], objs["dp"])])),
         "solver agreement"),
    ]
