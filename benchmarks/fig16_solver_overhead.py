"""Fig 16: constraint-solver execution time per resize decision.
Paper: 7.03 s average with CBC on their instance sizes.  We report the
legacy cache-only solve (CBC vs exact DP, the paper's comparison) plus
the full modern planning stack — ``solve_cluster_schedule`` with
heterogeneous fleets, transition costs and the typed-storage search at
realistic option counts — which is what the controller actually pays
per resize decision today."""
from __future__ import annotations

import numpy as np

from repro.core.plan import ResourcePlan, TransitionConfig
from repro.core.solver import solve_cache_schedule, solve_cluster_schedule
from repro.serving.perfmodel import SERVING_MODELS, SLOS

from benchmarks.common import SMOKE, CARBON, get_profile, save_result

FLEET_PLANS = [ResourcePlan.parse(f"serve={t}:{k}")
               for t in ("l40", "a100", "h100")
               for k in (1, 2, 3, 4)]
STORAGE_SPECS = ["dram:0.25tb+qlc_ssd:4tb", "dram:0.5tb+qlc_ssd:8tb",
                 "dram:1tb+qlc_ssd:16tb"]


def run():
    prof = get_profile("llama3-70b", "conversation")
    slo = SLOS[("llama3-70b", "chat")]
    model = SERVING_MODELS["llama3-70b"]
    rng = np.random.default_rng(0)
    times = {"cbc": [], "dp": [], "cluster": [], "storage": []}
    objs = {"cbc": [], "dp": []}
    hours = 6 if SMOKE else 24
    for trial in range(2 if SMOKE else 10):
        rates = rng.uniform(0.2, 1.6, hours)
        cis = rng.uniform(30, 300, hours)
        for use_ilp, name in [(True, "cbc"), (False, "dp")]:
            r = solve_cache_schedule(prof, rates, cis, slo, CARBON,
                                     use_ilp=use_ilp)
            times[name].append(r.solve_time_s)
            objs[name].append(r.objective_g)
        # the modern resize decision: fleets x sizes with switching
        # costs and dwell (what GreenCacheController pays hourly)
        r = solve_cluster_schedule(
            prof, rates, cis, slo, CARBON, plans=FLEET_PLANS,
            model=model, use_ilp=False, transitions=TransitionConfig(),
            min_dwell_hours=2, initial_plan=FLEET_PLANS[0])
        times["cluster"].append(r.solve_time_s)
        # the typed-storage search (tiered specs, wear-aware)
        r = solve_cluster_schedule(
            prof, rates, cis, slo, CARBON, plans=FLEET_PLANS[:4],
            storage=STORAGE_SPECS, model=model, use_ilp=False)
        times["storage"].append(r.solve_time_s)
    save_result("fig16_solver_overhead", {
        "cbc_times_s": times["cbc"], "dp_times_s": times["dp"],
        "cluster_times_s": times["cluster"],
        "storage_times_s": times["storage"]})
    n_cluster = len(FLEET_PLANS) * len(prof.sizes)
    return [
        ("fig16/cbc_avg_solve_s", float(np.mean(times["cbc"])),
         "paper: 7.03s on larger instance"),
        ("fig16/dp_avg_solve_s", float(np.mean(times["dp"])),
         "exact DP fallback"),
        ("fig16/dp_obj_within_5pct_of_cbc",
         float(np.mean([abs(a - b) / max(a, 1e-9) < 0.05
                        for a, b in zip(objs["cbc"], objs["dp"])])),
         "solver agreement"),
        ("fig16/cluster_avg_solve_s", float(np.mean(times["cluster"])),
         f"fleets+transitions+dwell, {n_cluster} options"),
        ("fig16/storage_avg_solve_s", float(np.mean(times["storage"])),
         "typed-storage search (tiered, wear-aware)"),
    ]
