"""Geo-distributed serving: carbon-aware global routing vs latency-only
and best-single-region baselines (PR-8 georouting subsystem).

Three standing regressions:

1. *Follow-the-green beats latency-only AND best single region.* Two
   regions run anti-phase duck-curve grids (same CISO trace, one region
   phase-shifted 12 h) with mirrored population RTTs, all well inside
   the conversation TTFT budget.  The latency-only router pins each
   population to its nearest region regardless of grid state; a single
   region is stuck with its own dirty hours.  Follow-the-green shifts
   the stream toward whichever region is in its clean phase, so on
   every seed it must emit strictly less total gCO2e than both
   baselines at equal-or-better request-weighted SLO attainment
   (within ``EPS_SLO``).

2. *Single-region bit-repro.* ``run_day(regions=[Region("solo")])``
   must bit-reproduce the vanilla ``run_day`` hour records — carbon,
   cache sizes, SLO, hit rates, plans all equal — both in the global
   record stream and the per-region sub-result, so the geo plumbing
   provably costs nothing when unused.

3. *Exact accounting.* On a tiered two-region day: every global hour
   record's carbon must equal the sum of its per-region records
   exactly (no float slack); every :class:`GeoHourLedger` must satisfy
   ``migrated_bytes == adopted_bytes + dropped_bytes`` with assigned
   request counts partitioning the hour's stream; and the per-tenant
   chargeback on each global record must sum to that hour's carbon
   bit-exactly.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import CarbonModel
from repro.core.controller import GreenCacheController
from repro.core.georouter import GeoRoutingConfig
from repro.core.profiler import run_profiler
from repro.serving.perfmodel import SERVING_MODELS
from repro.serving.regions import Region

from benchmarks.common import (SMOKE, cap_requests, clip_day,
                               save_result)

MODEL = "llama3-70b"
TASK = "conversation"
GRID = "CISO"                       # duck curve: clean midday, dirty evening
PEAK_RATE = 1.0                     # req/s per reference-capacity unit
RATES = [0.2, 0.5, 0.9, 1.3, 1.7]   # per capacity unit
SIZES = [0, 4, 8]
# l40:1 matters here: a green-drained region shrinks to one replica
# instead of idling a full fleet at the dirty grid's CI
FLEETS = ["l40:1", "l40:2", "l40:3", "l40:4"]
SCALE = 4.0
SHARES = {"gold": 0.25, "standard": 0.45, "scavenger": 0.30}

EPS_SLO = 0.01                      # ±1 pt attainment band
# sharp inverse-CI exponent: the dirty-phase region should drain to a
# trickle, not keep a straggler stream pinning its fleet at full power
GREEN = GeoRoutingConfig(policy="green", gamma=10.0)
# smoke clips to 8 h so the anti-phase CI crossing (~h5 on CISO) stays
# inside the window and follow-the-green has both phases to exploit
HOURS = 8
SEEDS = [11] if SMOKE else [11, 23]

_CACHE = {}


def _workload(seed, scale=SCALE):
    from repro.workloads.conversations import ConversationWorkload
    return ConversationWorkload(seed=seed, load_scale=scale)


def _profile():
    # smoke uses a wider rate grid and a longer measurement window than
    # the stock smoke profiler settings: routing decisions hinge on the
    # fleet-sizing economics, and 90 s cells mis-read attainment badly
    # enough to double-provision whichever region the router
    # concentrates on (the grid is still tiny — ~2 s wall)
    if "p" not in _CACHE:
        kw = dict(meas_seconds=240.0, ramp_seconds=40.0) if SMOKE else {}
        _CACHE["p"] = run_profiler(
            SERVING_MODELS[MODEL], TASK, _workload, CarbonModel(),
            rates=[0.2, 0.6, 1.1] if SMOKE else RATES,
            sizes_tb=SIZES[:2] if SMOKE else SIZES,
            warmup_prompts=cap_requests(8000, 400),
            policy="lcs_chat", **kw)
    return _CACHE["p"]


def _regions():
    """Anti-phase pair: same grid 12 h apart; mirrored RTTs pin the
    latency-only router to each population's home region.  The +1 base
    offset centers the smoke window (8 h) on the duck curve's phase
    crossing, so each region is the clean one for about half the
    window — over a full 24 h day the offset is immaterial."""
    west = Region.make("west", grid=GRID, seed=4, tz_offset_h=1,
                       rtt_ms={"na": 20.0, "eu": 90.0})
    east = Region.make("east", grid=GRID, seed=4, tz_offset_h=13,
                       rtt_ms={"na": 90.0, "eu": 20.0})
    return [west, east]


def _controller(seed, *, tiers=None, tier_cache_weights=None):
    return GreenCacheController(
        SERVING_MODELS[MODEL], _profile(), CarbonModel(), TASK,
        mode="greencache", policy="lcs_chat",
        plans=[f"cache=auto fleet={f}" for f in FLEETS],
        warm_requests=cap_requests(8000, 400), seed=seed,
        max_requests_per_hour=cap_requests(900),
        sizes_tb=SIZES[:2] if SMOKE else SIZES, rho_margin=0.05,
        tiers=tiers, tier_cache_weights=tier_cache_weights)


def _traces():
    from repro.workloads.traces import azure_rate_trace, ci_trace
    return clip_day(azure_rate_trace(PEAK_RATE * SCALE, seed=3),
                    ci_trace(GRID, seed=4), hours=HOURS)


def _histories(regions):
    """Full-day predictor histories (3 tiled days).  Smoke clips the
    simulated day to 8 h; tiling *that* snippet would hand the
    24 h-seasonal predictors a period-8 history and garble the hour-0
    forecasts, so the history keeps the real diurnal period."""
    from repro.workloads.traces import azure_rate_trace
    rate_hist = np.tile(azure_rate_trace(PEAK_RATE * SCALE, seed=3), 3)
    ci_hists = [np.tile(np.asarray(rg.cis) * rg.ci_scale, 3)
                for rg in regions]
    return rate_hist, ci_hists


def _day(seed, *, regions=None, geo=None, tiers=None,
         tier_cache_weights=None):
    ctl = _controller(seed, tiers=tiers,
                      tier_cache_weights=tier_cache_weights)
    rate_trace, cis = _traces()
    kw = {}
    if regions is not None:
        rate_hist, ci_hists = _histories(regions)
        kw = dict(rate_history=rate_hist, ci_history=ci_hists)
    res = ctl.run_day(_workload, rate_trace, cis,
                      regions=regions, geo=geo, **kw)
    return ctl, res


def _carbon(res) -> float:
    return float(sum(h.carbon_g for h in res.hours))


def _slo(res) -> float:
    n = sum(h.num_requests for h in res.hours)
    return float(sum(h.slo_frac * h.num_requests
                     for h in res.hours) / max(n, 1))


def _same_records(a, b) -> bool:
    return len(a.hours) == len(b.hours) and all(
        ha.carbon_g == hb.carbon_g and ha.cache_tb == hb.cache_tb
        and ha.operational_g == hb.operational_g
        and ha.slo_frac == hb.slo_frac and ha.hit_rate == hb.hit_rate
        and ha.num_requests == hb.num_requests
        and ha.p90_ttft == hb.p90_ttft and ha.plan == hb.plan
        and ha.n_replicas == hb.n_replicas
        for ha, hb in zip(a.hours, b.hours))


def _routing_rows(out, payload):
    """Headline: follow-the-green < latency-only and < best single
    region on gCO2e, at equal-or-better SLO, per seed."""
    ok_all = True
    for seed in SEEDS:
        _, green = _day(seed, regions=_regions(), geo=GREEN)
        _, lat = _day(seed, regions=_regions(), geo="latency")
        _, west = _day(seed, regions=[_regions()[0]])
        _, east = _day(seed, regions=[_regions()[1]])
        g_g, g_l = _carbon(green), _carbon(lat)
        g_w, g_e = _carbon(west), _carbon(east)
        single, name_s = (west, "west") if g_w <= g_e else (east, "east")
        g_s = _carbon(single)
        s_g, s_l, s_s = _slo(green), _slo(lat), _slo(single)
        ok = (g_g < g_l and g_g < g_s
              and s_g >= s_l - EPS_SLO and s_g >= s_s - EPS_SLO)
        ok_all = ok_all and ok
        out.append((f"georouting/green_total_g_seed{seed}", g_g,
                    f"slo={s_g:.3f}"))
        out.append((f"georouting/latency_total_g_seed{seed}", g_l,
                    f"slo={s_l:.3f}"))
        out.append((f"georouting/best_single_total_g_seed{seed}", g_s,
                    f"{name_s} slo={s_s:.3f} (west={g_w:.1f} "
                    f"east={g_e:.1f})"))
        out.append((f"georouting/green_wins_seed{seed}", float(ok),
                    f"saves {g_l - g_g:.1f}g vs latency, "
                    f"{g_s - g_g:.1f}g vs {name_s}"))
        lat_pcts = green.latency
        for metric in ("ttft", "tpot"):
            for q in ("p50", "p95", "p99"):
                out.append((
                    f"georouting/green_latency_seed{seed}/{metric}_{q}",
                    lat_pcts[metric][q],
                    f"day {metric.upper()} {q} under follow-the-green "
                    f"(estimator={lat_pcts['estimator']})"))
        payload[f"seed{seed}"] = dict(
            green_g=g_g, latency_g=g_l, single_g=g_s,
            single_region=name_s, green_slo=s_g, latency_slo=s_l,
            single_slo=s_s, wins=ok, green_latency=lat_pcts)
    return ok_all


def _bitrepro_rows(out, payload):
    """One-region geo run must bit-reproduce vanilla ``run_day``."""
    ctl_v = _controller(11)
    rate_trace, cis = _traces()
    vanilla = ctl_v.run_day(_workload, rate_trace, cis)
    ctl_g = _controller(11)
    geo = ctl_g.run_day(_workload, rate_trace, cis,
                        regions=[Region("solo")])
    ok = (_same_records(vanilla, geo)
          and _same_records(vanilla, geo.regions["solo"]))
    out.append(("georouting/single_region_bit_repro", float(ok),
                "regions=[solo] hour records == vanilla run_day"))
    payload["single_region_bit_repro"] = ok
    return ok


def _accounting_rows(out, payload):
    """Exact partition of carbon/requests across regions, exact
    migration byte ledgers, exact per-tenant chargeback."""
    regions = _regions()
    names = [r.name for r in regions]
    ctl, res = _day(11, regions=regions, geo=GREEN, tiers=SHARES,
                    tier_cache_weights=True)
    part_ok = all(
        h.carbon_g == sum(res.regions[n].hours[i].carbon_g
                          for n in names)
        and h.num_requests == sum(res.regions[n].hours[i].num_requests
                                  for n in names)
        for i, h in enumerate(res.hours))
    ledgers = ctl.last_geo.ledgers
    led_ok = all(lg.migrated_bytes == lg.adopted_bytes + lg.dropped_bytes
                 for lg in ledgers) and all(
                     sum(lg.assigned) == res.hours[lg.hour].num_requests
                     for lg in ledgers)
    charge_ok = all(
        h.tenants is not None
        and sum(d["carbon_g"] for d in h.tenants.values()) == h.carbon_g
        and sum(d["requests"] for d in h.tenants.values())
        == h.num_requests
        for h in res.hours)
    moved = float(sum(lg.migrated_bytes for lg in ledgers))
    out.append(("georouting/carbon_partitions_exactly", float(part_ok),
                "global hour carbon == west + east, bit-exact"))
    out.append(("georouting/migration_ledger_exact", float(led_ok),
                f"migrated==adopted+dropped; {moved / 1e9:.2f} GB moved"))
    out.append(("georouting/tenant_chargeback_exact", float(charge_ok),
                "per-tenant gCO2e sums to hourly total, bit-exact"))
    payload["partition_exact"] = part_ok
    payload["ledger_exact"] = led_ok
    payload["chargeback_exact"] = charge_ok
    payload["migrated_gb"] = moved / 1e9
    return part_ok and led_ok and charge_ok


def run():
    out = []
    payload = {}
    route_ok = _routing_rows(out, payload)
    repro_ok = _bitrepro_rows(out, payload)
    acct_ok = _accounting_rows(out, payload)
    headline = route_ok and repro_ok and acct_ok
    out.append(("georouting/headline_pass", float(headline),
                f"routing={route_ok} bitrepro={repro_ok} "
                f"accounting={acct_ok}"))
    save_result("georouting", payload)
    if not headline:
        # NaN fails the --smoke harness: a lost headline is a CI
        # failure, not a quietly-odd CSV row
        out.append(("georouting/headline_FAILED", float("nan"),
                    "one or more headline assertions failed"))
    return out
