"""Radix prefix-tree KV sharing vs whole-context keying.

Both controller days consume the *same* structured conversation stream
(``ConversationWorkload(prefix=True)``: shared system prompt + one block
per retained history turn).  The whole-context day keys the flat store on
``conv-{cid}`` and ignores the blocks — the legacy behaviour; the prefix
day runs the ``RadixKVStore`` end to end (profiler, solver sizing,
serving), so the shared system prompts deduplicate into one tree node
each, window-truncated histories keep their matched prefix instead of
missing outright, and partial hits shorten prefill proportionally.

Rows:

* **prefix beats whole-context (FR, conversation trace, seeds 11/23)** —
  the solver co-decides (fleet, cache size) hourly over {l40:2, l40:3} x
  sizes; partial hits re-prefill only the unmatched suffix, so the
  prefix day holds the two-replica fleet (and a smaller cache) through
  hours where whole-context keying needs the third server or more cache
  to meet the SLO.  Derived row: the prefix day's solver-chosen plan
  comes in at *strictly lower* total gCO2e with equal-or-better SLO
  attainment than the same candidate set under whole-context keying.
* **agent-loop sharing** — the branching ``AgentLoopWorkload`` (tool-use
  episodes that fork their context) measured engine-level: whole-context
  keying reuses almost nothing (every fork's full path is unique), the
  radix tree reuses the shared trunk — reported as matched-token
  fractions.
* **exact-key bit-repro** — with ``blocks=None`` (a legacy unstructured
  stream) the ``RadixKVStore`` must bit-reproduce the flat ``KVStore``
  trajectory: identical TTFT arrays and identical hit/eviction/byte
  ledgers across shared and partitioned engines.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import CarbonModel
from repro.core.controller import GreenCacheController
from repro.core.plan import ResourcePlan
from repro.core.policies import POLICIES
from repro.core.profiler import run_profiler
from repro.serving.cluster import make_cluster
from repro.serving.perfmodel import SERVING_MODELS
from repro.workloads import (ConversationWorkload, make_poisson_arrivals,
                             sample_many)
from repro.workloads.agents import AgentLoopWorkload

from benchmarks.common import (SMOKE, cap_requests, clip_day,
                               profiler_kwargs, save_result)

MODEL = "llama3-70b"
GRID = "FR"
EPS_SLO = 0.02
FLEETS = ["l40:2", "l40:3"]          # solver co-decides fleet x cache
SCALE = 3.0                          # conversation pool spans the fleet
RATES = [0.3, 0.7, 1.1, 1.5, 1.9]    # per reference-server profile grid
SIZES = [0, 1, 2, 4, 8, 12, 16]
PEAK_RATE = 3.2                      # cluster req/s at the diurnal peak

_CACHE = {}


def _workload(seed, scale=SCALE):
    return ConversationWorkload(seed=seed, load_scale=scale, prefix=True)


def _profile(prefix_aware: bool):
    """Both profiles measure the same structured stream; only the store
    changes — that isolates the caching scheme as the lone variable."""
    if prefix_aware not in _CACHE:
        _CACHE[prefix_aware] = run_profiler(
            SERVING_MODELS[MODEL], "conversation", _workload, CarbonModel(),
            rates=RATES[:2] if SMOKE else RATES,
            sizes_tb=SIZES[:2] + [SIZES[-1]] if SMOKE else SIZES,
            warmup_prompts=cap_requests(12000, 400),
            policy="lcs_chat", prefix_aware=prefix_aware,
            **profiler_kwargs())
    return _CACHE[prefix_aware]


def _day(prefix: bool, seed: int):
    from repro.workloads.traces import azure_rate_trace, ci_trace

    ctl = GreenCacheController(
        SERVING_MODELS[MODEL], _profile(prefix), CarbonModel(),
        "conversation", mode="greencache", policy="lcs_chat",
        plans=[ResourcePlan.single(None, fleet=f) for f in FLEETS],
        warm_requests=cap_requests(12000, 400), seed=seed,
        max_requests_per_hour=cap_requests(2400), rho_margin=0.0,
        prefix_caching=prefix)
    rate_trace, cis = clip_day(azure_rate_trace(PEAK_RATE, seed=3),
                               ci_trace(GRID, seed=4))
    return ctl.run_day(_workload, rate_trace, cis)


def _row(name, res):
    return (f"prefix_sharing/{GRID}/{name}/total_g", res.total_carbon_g,
            f"slo={res.slo_attainment:.3f} avg_tb={res.avg_cache_tb:.1f} "
            f"rep={res.avg_replicas:.2f} "
            f"hit={float(np.mean([h.hit_rate for h in res.hours])):.3f}")


# ---- agent-loop sharing (engine-level) ---------------------------- #
def _agent_matched(prefix: bool) -> float:
    """Mean matched-token fraction of the branching agent trace under
    radix vs whole-context keying (identical stream, identical engine)."""
    m = SERVING_MODELS[MODEL]
    wl = AgentLoopWorkload(seed=5, active_pool=cap_requests(3000, 300))
    n = cap_requests(9000, 900)
    arr = make_poisson_arrivals(np.full(8, 2.5), seed=5, max_requests=n)
    reqs = sample_many(wl, arr)
    eng = make_cluster(m, CarbonModel(), cache_tb=4.0,
                       policy=POLICIES["lcs_chat"], n_replicas=2,
                       router="cache_affinity", prefix_caching=prefix)
    eng.run(reqs, ci_fn=lambda _: 0.0, cache_tb=4.0)
    return float(np.mean([r.reused_tokens / max(r.prompt_tokens, 1)
                          for r in reqs]))


# ---- exact-key bit-repro ------------------------------------------ #
def _bit_repro() -> bool:
    """Legacy unstructured stream through twin engines — flat ``KVStore``
    vs exact-key ``RadixKVStore`` — must produce identical TTFT arrays
    and identical store ledgers (hits, evictions, bytes), shared and
    partitioned."""
    m = SERVING_MODELS[MODEL]
    n = cap_requests(8000, 800)
    for partitioned in (False, True):
        runs = []
        for radix in (False, True):
            wl = ConversationWorkload(seed=11, active_pool=2000)
            arr = make_poisson_arrivals(np.full(8, 2.0), seed=11,
                                        max_requests=n)
            reqs = sample_many(wl, arr)
            eng = make_cluster(m, CarbonModel(), cache_tb=0.5,
                               policy=POLICIES["lcs_chat"], n_replicas=2,
                               router="cache_affinity",
                               partitioned=partitioned,
                               prefix_caching=radix)
            res = eng.run(reqs, ci_fn=lambda _: 100.0, cache_tb=0.5)
            runs.append((res, [vars(s.stats).copy() for s in eng.stores]))
        (r0, s0), (r1, s1) = runs
        if not (np.array_equal(r0.ttft, r1.ttft) and s0 == s1
                and r0.carbon_g == r1.carbon_g):
            return False
    return True


def run():
    out = []
    seeds = [11] if SMOKE else [11, 23]
    payload = {"seeds": {}}
    wins = []
    for seed in seeds:
        flat = _day(False, seed)
        shared = _day(True, seed)
        out.append(_row(f"seed{seed}/whole_context", flat))
        out.append(_row(f"seed{seed}/prefix", shared))
        wins.append(shared.total_carbon_g < flat.total_carbon_g
                    and shared.slo_attainment
                    >= flat.slo_attainment - EPS_SLO)
        payload["seeds"][seed] = {
            k: {"total_g": r.total_carbon_g, "slo": r.slo_attainment,
                "avg_cache_tb": r.avg_cache_tb,
                "hit_rates": [h.hit_rate for h in r.hours],
                "hourly_sizes": [h.cache_tb for h in r.hours]}
            for k, r in [("whole_context", flat), ("prefix", shared)]}
    beats = all(wins)
    out.append((f"prefix_sharing/{GRID}/prefix_beats_whole_context",
                float(beats),
                f"< gCO2e at >= SLO-{EPS_SLO} on {len(wins)} seed(s)"))

    agent_flat = _agent_matched(False)
    agent_radix = _agent_matched(True)
    out.append(("prefix_sharing/agent/whole_context_matched_frac",
                agent_flat, "branching agent loop, flat keying"))
    out.append(("prefix_sharing/agent/radix_matched_frac", agent_radix,
                "shared trunks reused across forks"))
    out.append(("prefix_sharing/agent/radix_gains", float(
        agent_radix > agent_flat + 0.05),
        "radix matched-token fraction clears flat by > 5pts"))

    repro_ok = _bit_repro()
    out.append(("prefix_sharing/exact_key_bit_repro", float(repro_ok),
                "blocks=None radix == flat KVStore trajectory"))
    payload["prefix_beats_whole_context"] = bool(beats)
    payload["agent_matched"] = {"whole_context": agent_flat,
                                "radix": agent_radix}
    payload["exact_key_bit_repro"] = repro_ok
    save_result("prefix_sharing", payload)
    return out
