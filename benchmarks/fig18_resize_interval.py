"""Fig 18: carbon savings vs cache-resize interval (1h default; longer
intervals must hold a larger size for the whole interval, reducing
savings)."""
from __future__ import annotations

from repro.core.controller import GreenCacheController
from repro.serving.perfmodel import SERVING_MODELS
from repro.workloads.traces import azure_rate_trace, ci_trace

from benchmarks.common import (CARBON, TASKS, WARMUP, cap_requests,
                               clip_day, get_profile, save_result)

INTERVALS = [1, 2, 4, 8]


def run():
    m = SERVING_MODELS["llama3-70b"]
    prof = get_profile("llama3-70b", "conversation")
    rates = azure_rate_trace(1.6, seed=3)
    out = []
    rows = []
    for grid in ["FR", "CISO"]:
        day_rates, cis = clip_day(rates, ci_trace(grid, seed=4))
        full = GreenCacheController(
            m, prof, CARBON, "conversation", mode="full",
            policy="lcs_chat", warm_requests=WARMUP["conversation"],
            max_requests_per_hour=cap_requests(1000)).run_day(
                TASKS["conversation"]["factory"], day_rates, cis)
        for iv in INTERVALS:
            gc = GreenCacheController(
                m, prof, CARBON, "conversation", mode="greencache",
                policy="lcs_chat", warm_requests=WARMUP["conversation"],
                resize_interval_h=iv,
                max_requests_per_hour=cap_requests(1000)).run_day(
                    TASKS["conversation"]["factory"], day_rates, cis)
            saving = 1 - gc.carbon_per_request_g / full.carbon_per_request_g
            rows.append({"grid": grid, "interval_h": iv, "saving": saving,
                         "avg_cache_tb": gc.avg_cache_tb})
            out.append((f"fig18/{grid}/interval{iv}h/saving", saving,
                        f"cache={gc.avg_cache_tb:.1f}TB"))
    save_result("fig18_resize_interval", {"rows": rows})
    for grid in ["FR", "CISO"]:
        g = [r for r in rows if r["grid"] == grid]
        out.append((f"fig18/{grid}/longer_interval_not_better",
                    float(g[0]["saving"] >= g[-1]["saving"] - 0.02),
                    "1h >= 8h savings"))
    return out
