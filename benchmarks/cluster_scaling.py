"""Multi-replica cluster scaling: router comparison and vectorized-engine
speedup (new in the cluster-engine PR; no direct paper figure).

Sweeps n_replicas x router for the 70B chat task at proportionally scaled
rates, with the cache partitioned per replica — the regime where routing
matters: cache_affinity keeps repeated contexts on the replica holding
their KV, so its token hit rate should approach the shared-cache ceiling
while round_robin scatters contexts across partitions. Also reports the
single-replica vectorized-vs-seed-loop engine speedup on a common trace.
"""
from __future__ import annotations

import copy
import time

import numpy as np

from repro.core.carbon import CarbonModel
from repro.core.kvstore import KVStore
from repro.core.policies import POLICIES
from repro.serving.engine import ServingEngine
from repro.serving.cluster import ClusterEngine
from repro.serving.perfmodel import SERVING_MODELS, SLOS

from benchmarks.common import cap_requests, measure_cell, save_result

MODEL = "llama3-70b"
BASE_RATE = 1.2           # per-replica arrival rate (req/s)
CACHE_TB_PER_REPLICA = 4.0
REPLICAS = [1, 2, 4]
ROUTERS = ["round_robin", "least_loaded", "cache_affinity"]


def _speedup_row(n_requests: int = 24000, warm: int = 12000, reps: int = 3):
    """Single-replica vectorized engine vs the seed per-request loop."""
    from repro.workloads.conversations import ConversationWorkload
    from repro.workloads.traces import make_poisson_arrivals

    m = SERVING_MODELS[MODEL]
    cm = CarbonModel()
    from repro.workloads import sample_many
    wl = ConversationWorkload(seed=7)
    arr = make_poisson_arrivals(np.full(48, 1.5), seed=8,
                                max_requests=n_requests)
    base = sample_many(wl, arr)

    def run_once(engine_cls, cache_tb=4.0):
        reqs = [copy.copy(r) for r in base]
        store = KVStore(cache_tb * 1e12, POLICIES["lcs_chat"],
                        m.kv_bytes_per_token)
        eng = engine_cls(m, store, cm)
        eng.warm(reqs[:warm])
        t0 = time.perf_counter()
        res = eng.run(reqs[warm:], ci_fn=lambda t: 50.0, cache_tb=cache_tb)
        return time.perf_counter() - t0, res

    t_seed = min(run_once(ServingEngine)[0] for _ in range(reps))
    t_clus, res = min((run_once(ClusterEngine) for _ in range(reps)),
                      key=lambda x: x[0])
    return t_seed, t_clus, res


def run():
    out = []
    rows = []
    slo = SLOS[(MODEL, "chat")]
    for n in REPLICAS:
        for router in ROUTERS:
            if n == 1 and router != "round_robin":
                continue            # one replica: routing is moot
            res = measure_cell(
                MODEL, "conversation", cache_tb=CACHE_TB_PER_REPLICA * n,
                rate=BASE_RATE * n, ci=124.0, n_replicas=n,
                router=router if n > 1 else None, partitioned=(n > 1),
                n_seconds=300.0)
            rows.append({
                "n_replicas": n, "router": router if n > 1 else "single",
                "hit_rate": res.token_hit_rate,
                "p90_ttft": res.p90("ttft"),
                "carbon_per_req_g": res.carbon_per_request_g,
                "slo": res.slo_attainment(slo),
            })
            out.append((f"cluster/{n}rep/{rows[-1]['router']}/hit_rate",
                        res.token_hit_rate,
                        f"p90_ttft={res.p90('ttft'):.2f}s "
                        f"slo={rows[-1]['slo']:.3f}"))
    # affinity must retain hits under partitioning; round-robin scatters
    for n in (2, 4):
        aff = next(r for r in rows if r["n_replicas"] == n
                   and r["router"] == "cache_affinity")
        rr = next(r for r in rows if r["n_replicas"] == n
                  and r["router"] == "round_robin")
        out.append((f"cluster/{n}rep/affinity_hit_gain",
                    aff["hit_rate"] - rr["hit_rate"],
                    "cache_affinity - round_robin token hit rate"))

    t_seed, t_clus, res = _speedup_row(
        n_requests=cap_requests(24000, 4000),
        warm=cap_requests(12000, 2000))
    out.append(("cluster/engine_speedup_vs_seed", t_seed / max(t_clus, 1e-9),
                f"seed {t_seed:.2f}s -> vectorized {t_clus:.2f}s "
                f"({res.num_requests} reqs)"))
    save_result("cluster_scaling", {"rows": rows,
                                    "speedup": t_seed / max(t_clus, 1e-9)})
    return out
