"""Roofline table from the multi-pod dry-run artifacts
(experiments/dryrun.json): the three terms, dominant bottleneck, and
MODEL_FLOPS/HLO_FLOPs per (arch × shape × mesh). See EXPERIMENTS.md
§Roofline."""
from __future__ import annotations

import json
import os

from benchmarks.common import save_result

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun.json")


def load():
    if not os.path.exists(DRYRUN):
        return []
    with open(DRYRUN) as f:
        return json.load(f)


def run():
    recs = [r for r in load() if "error" not in r]
    out = []
    if not recs:
        return [("roofline/dryrun_missing", 0.0,
                 "run python -m repro.launch.dryrun first")]
    single = [r for r in recs if r["mesh"] == "single"]
    multi = [r for r in recs if r["mesh"] == "multi"]
    out.append(("roofline/combos_single_ok", float(len(single)),
                "of 40 (arch x shape)"))
    out.append(("roofline/combos_multi_ok", float(len(multi)),
                "of 40 — multi-pod 512-chip mesh lowers"))
    dom = {}
    for r in single:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        key = f"roofline/{r['arch']}/{r['shape']}"
        tot = r["compute_s"] + 1e-30
        out.append((key + "/dominant_term_s",
                    r[f"{r['dominant']}_s"],
                    f"{r['dominant']}-bound; useful_flops_ratio="
                    f"{r['useful_flops_ratio']:.2f}"))
    for k, v in sorted(dom.items()):
        out.append((f"roofline/dominant_{k}_count", float(v),
                    "single-pod baselines"))
    save_result("roofline_report", {"records": recs})
    return out
