"""Fig 19: sensitivity to SSD lifespan (3-7 y): shorter lifetimes raise
amortized embodied carbon, increasing GreenCache's savings (paper: up to
11.9 % at 3 y). Fixed 1.5 req/s chat, ES-average CI.

The sweep is a *device-parameter* sweep over the storage registry: each
point rescales the reference ``nvme_gen4`` device's calendar lifetime
and projects it onto the pricing path via ``device_hardware_spec`` — at
the default 5-year device this is exactly the seed ``HardwareSpec``, so
the middle point reproduces the pre-registry figure bit-for-bit."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.carbon import GRID_CI
from repro.core.controller import GreenCacheController
from repro.core.carbon import CarbonModel
from repro.core.storage import (DEFAULT_DEVICE, STORAGE_DEVICES,
                                device_hardware_spec)
from repro.serving.perfmodel import SERVING_MODELS

from benchmarks.common import (TASKS, WARMUP, cap_requests, clip_day,
                               get_profile, save_result)

LIFESPANS = [3.0, 5.0, 7.0]


def run():
    m = SERVING_MODELS["llama3-70b"]
    prof = get_profile("llama3-70b", "conversation")
    rows = []
    for lt in LIFESPANS:
        dev = dataclasses.replace(STORAGE_DEVICES[DEFAULT_DEVICE],
                                  lifetime_years=lt)
        cm = CarbonModel(hw=device_hardware_spec(dev))
        rates, cis = clip_day(np.full(12, 1.5),
                              np.full(12, GRID_CI["ES"]))
        res = {}
        for mode in ["full", "greencache"]:
            ctl = GreenCacheController(
                m, prof, cm, "conversation", mode=mode, policy="lcs_chat",
                warm_requests=WARMUP["conversation"],
                max_requests_per_hour=cap_requests(1000))
            res[mode] = ctl.run_day(TASKS["conversation"]["factory"],
                                    rates, cis).carbon_per_request_g
        rows.append({"lifetime_y": lt,
                     "saving": 1 - res["greencache"] / res["full"]})
    save_result("fig19_ssd_lifetime", {"rows": rows})
    out = [(f"fig19/lt{int(r['lifetime_y'])}y/saving", r["saving"],
            "GreenCache vs Full") for r in rows]
    out.append(("fig19/shorter_lifetime_more_saving",
                float(rows[0]["saving"] >= rows[-1]["saving"] - 0.02),
                "paper: 3y gives the most savings"))
    return out
