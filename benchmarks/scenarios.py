"""Scenario gauntlet: multi-tenant SLO tiers under hostile traffic.

Three standing regressions (no direct paper figure; the scenarios stress
the solver/engine stack the paper's steady-state tables never exercise):

1. *Tiered vs tier-blind under a flash crowd.* A step flash crowd
   multiplies the arrival rate mid-day. The tier-aware solver thins each
   protected tier's effective rate by its cumulative priority share
   (scavengers add load but no constraint), so it can provision less
   fleet while the engine's priority queue protects gold; the tier-blind
   solver sees one aggregate SLO and over-provisions (or misses).
   Headline row: on every seed the tiered day must weakly Pareto-beat
   the blind day on (gold SLO attainment, total gCO2e) — gold SLO no
   worse than ``EPS_SLO`` below blind at no more carbon, or strictly
   less carbon at no worse gold SLO.

2. *Mid-hour replica failure.* A fail-stop replica loss at hour
   ``FAIL_H`` + 0.5 shrinks the ring immediately (keys orphaned, not
   migrated); the next hourly ``apply()`` re-boots capacity through the
   PR-4 transition machinery. The failure hour's SLO may dip at most
   ``MAX_DIP`` below the no-failure day; by ``RECOVER_H`` hours later
   attainment must be back within ``EPS_SLO``. The surviving stores'
   byte ledgers must stay exactly consistent (``used_bytes`` equals the
   sum of live entry sizes).

3. *Regression anchor.* An identity ``Scenario()`` with no tier shares
   must bit-reproduce the vanilla (scenario=None, single-tier) hour
   records — carbon, cache sizes, SLO, hit rates, plans all equal —
   so the scenario/tier plumbing provably costs nothing when unused.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import CarbonModel
from repro.core.controller import GreenCacheController
from repro.core.policies import POLICIES
from repro.core.profiler import run_profiler
from repro.serving.cluster import make_cluster
from repro.serving.perfmodel import SERVING_MODELS
from repro.workloads import (FlashCrowd, ReplicaFailure, Scenario,
                             make_poisson_arrivals, sample_many)

from benchmarks.common import (SMOKE, cap_requests, clip_day,
                               profiler_kwargs, save_result)

MODEL = "llama3-70b"
TASK = "conversation"
GRID = "FR"
PEAK_RATE = 1.0                     # req/s per reference-capacity unit
RATES = [0.2, 0.5, 0.9, 1.3, 1.7]   # per capacity unit
SIZES = [0, 4, 8]
FLEETS = ["l40:2", "l40:3", "l40:4"]
SCALE = 4.0                         # widest candidate (l40:4) capacity
SHARES = {"gold": 0.25, "standard": 0.45, "scavenger": 0.30}

EPS_SLO = 0.01                      # ±1 pt attainment band
MAX_DIP = 0.25                      # worst tolerated failure-hour SLO dip
FAIL_H = 3 if SMOKE else 12         # replica dies at FAIL_H + 0.5
RECOVER_H = 2                       # hours until SLO must be back

_CACHE = {}


def _workload(seed, scale=SCALE):
    from repro.workloads.conversations import ConversationWorkload
    return ConversationWorkload(seed=seed, load_scale=scale)


def _profile():
    if "p" not in _CACHE:
        _CACHE["p"] = run_profiler(
            SERVING_MODELS[MODEL], TASK, _workload, CarbonModel(),
            rates=RATES[:2] if SMOKE else RATES,
            sizes_tb=SIZES[:2] if SMOKE else SIZES,
            warmup_prompts=cap_requests(8000, 400),
            policy="lcs_chat", **profiler_kwargs())
    return _CACHE["p"]


def _day(*, seed: int = 11, scenario=None, tiers=None,
         tier_aware: bool = True):
    from repro.workloads.traces import azure_rate_trace, ci_trace
    ctl = GreenCacheController(
        SERVING_MODELS[MODEL], _profile(), CarbonModel(), TASK,
        mode="greencache", policy="lcs_chat",
        plans=[f"cache=auto fleet={f}" for f in FLEETS],
        warm_requests=cap_requests(8000, 400), seed=seed,
        max_requests_per_hour=cap_requests(900),
        sizes_tb=SIZES[:2] if SMOKE else SIZES, rho_margin=0.0,
        tiers=tiers, tier_aware_solver=tier_aware)
    rate_trace, cis = clip_day(azure_rate_trace(PEAK_RATE * SCALE, seed=3),
                               ci_trace(GRID, seed=4), hours=6)
    res = ctl.run_day(_workload, rate_trace, cis, scenario=scenario)
    return ctl, res


def _same_records(a, b) -> bool:
    return len(a.hours) == len(b.hours) and all(
        ha.carbon_g == hb.carbon_g and ha.cache_tb == hb.cache_tb
        and ha.slo_frac == hb.slo_frac and ha.hit_rate == hb.hit_rate
        and ha.plan == hb.plan for ha, hb in zip(a.hours, b.hours))


def _ledger_consistent(engine) -> bool:
    return all(st.used_bytes
               == sum(e.size_bytes for e in st.entries.values())
               for st in engine.stores)


def _flash_crowd_rows(out, payload):
    """Headline 1: tiered weakly Pareto-beats tier-blind on
    (gold SLO, total carbon) under a flash crowd, every seed."""
    seeds = [11] if SMOKE else [11, 23]
    wins = []
    payload["flash_crowd"] = {}
    for seed in seeds:
        sc = FlashCrowd(hour=1 if SMOKE else 9, duration_h=2,
                        magnitude=2.5, seed=seed)
        _, tiered = _day(seed=seed, scenario=sc, tiers=SHARES,
                         tier_aware=True)
        _, blind = _day(seed=seed, scenario=sc, tiers=SHARES,
                        tier_aware=False)
        gt = tiered.per_tier["gold"]["slo_frac"]
        gb = blind.per_tier["gold"]["slo_frac"]
        ct, cb = tiered.total_carbon_g, blind.total_carbon_g
        # weak Pareto: no worse on both axes (within the SLO band), and
        # not strictly worse on either
        wins.append(gt >= gb - EPS_SLO and ct <= cb * (1 + 1e-9))
        out.append((f"scenarios/{GRID}/seed{seed}/tiered/total_g", ct,
                    f"gold_slo={gt:.3f} "
                    f"gold_g_per_req="
                    f"{tiered.per_tier['gold']['g_per_request']:.3g}"))
        out.append((f"scenarios/{GRID}/seed{seed}/blind/total_g", cb,
                    f"gold_slo={gb:.3f}"))
        payload["flash_crowd"][seed] = {
            "tiered": {"total_g": ct, "gold_slo": gt,
                       "per_tier": tiered.per_tier},
            "blind": {"total_g": cb, "gold_slo": gb,
                      "per_tier": blind.per_tier}}
    beats = all(wins)
    out.append((f"scenarios/{GRID}/tiered_pareto_beats_blind", float(beats),
                f"gold SLO within {EPS_SLO} at <= carbon on "
                f"{sum(wins)}/{len(wins)} seed(s)"))
    payload["tiered_pareto_beats_blind"] = bool(beats)
    return beats


def _failure_rows(out, payload):
    """Headline 2: mid-hour fail-stop recovers within a bounded dip."""
    _, base = _day(seed=11)
    ctl, hit = _day(seed=11,
                    scenario=ReplicaFailure(hour=FAIL_H, frac=0.5,
                                            replica=0))
    dip = base.hours[FAIL_H].slo_frac - hit.hours[FAIL_H].slo_frac
    rec_h = min(FAIL_H + RECOVER_H, len(hit.hours) - 1)
    resid = base.hours[rec_h].slo_frac - hit.hours[rec_h].slo_frac
    ledger = _ledger_consistent(ctl.last_engine)
    ok = (dip <= MAX_DIP and resid <= EPS_SLO and ledger
          and all(np.isfinite(h.carbon_g) for h in hit.hours))
    out.append((f"scenarios/{GRID}/failure/slo_dip", dip,
                f"hour={FAIL_H} recovery_resid={resid:.4f} "
                f"ledger_ok={ledger}"))
    out.append((f"scenarios/{GRID}/failure_recovers_bounded", float(ok),
                f"dip<={MAX_DIP} and back within {EPS_SLO} after "
                f"{RECOVER_H}h"))
    payload["failure"] = {
        "dip": dip, "recovery_residual": resid, "ledger_ok": ledger,
        "base_slo": [h.slo_frac for h in base.hours],
        "hit_slo": [h.slo_frac for h in hit.hours],
        "transitions": [h.transition for h in hit.hours]}
    return ok


def _partitioned_loss_row(out, payload):
    """Direct engine check: fail-stop on a *partitioned* cluster drops
    the dead shard's keys and leaves every survivor's byte ledger
    exactly consistent."""
    m = SERVING_MODELS[MODEL]
    eng = make_cluster(m, CarbonModel(), cache_tb=3 * 0.5,
                       policy=POLICIES["lcs_chat"], n_replicas=3,
                       router="cache_affinity", partitioned=True)
    wl = _workload(5)
    arr = make_poisson_arrivals(np.full(96, 1.5), seed=6,
                                max_requests=cap_requests(3000, 600))
    eng.warm(sample_many(wl, arr))
    before = sum(len(st.entries) for st in eng.stores)
    tr = eng.fail_replica(1, now=0.0)
    ok = (tr.dropped_keys > 0 and eng.n_replicas == 2
          and _ledger_consistent(eng)
          and sum(len(st.entries) for st in eng.stores)
          == before - tr.dropped_keys)
    out.append(("scenarios/partitioned_failure_drops_keys",
                float(tr.dropped_keys),
                f"survivor ledgers consistent={ok}"))
    payload["partitioned_loss"] = {"dropped": tr.dropped_keys, "ok": ok}
    return ok


def run():
    out = []
    payload = {}
    pareto_ok = _flash_crowd_rows(out, payload)
    fail_ok = _failure_rows(out, payload)
    part_ok = _partitioned_loss_row(out, payload)

    # regression anchor: identity scenario + no tiers == plain run
    _, vanilla = _day(seed=11)
    _, ident = _day(seed=11, scenario=Scenario())
    repro_ok = _same_records(vanilla, ident)
    out.append(("scenarios/identity_bit_reproduces_vanilla",
                float(repro_ok),
                "Scenario() hour records == scenario=None"))
    payload["identity_bit_repro"] = repro_ok

    # day-level latency percentiles (tracing is off here, so these are
    # the streaming P² estimates; HourRecord carries the exact per-hour
    # p50/p95/p99 alongside)
    lat = vanilla.latency
    for metric in ("ttft", "tpot"):
        for q in ("p50", "p95", "p99"):
            out.append((f"scenarios/{GRID}/latency/{metric}_{q}",
                        lat[metric][q],
                        f"day {metric.upper()} {q} "
                        f"(estimator={lat['estimator']})"))
    payload["latency"] = lat

    gauntlet = pareto_ok and fail_ok and part_ok and repro_ok
    out.append(("scenarios/gauntlet_pass", float(gauntlet),
                f"pareto={pareto_ok} failure={fail_ok} "
                f"partitioned={part_ok} identity={repro_ok}"))
    save_result("scenarios", payload)
    if not gauntlet:
        # NaN value fails the --smoke harness: a broken gauntlet is a
        # CI failure, not a quietly-odd CSV row
        out.append(("scenarios/gauntlet_FAILED", float("nan"),
                    "one or more headline assertions failed"))
    return out
