"""Fig 6: latency/speedup and hit rate vs cache size at 1.5 prompts/s
(Takeaway 3: benefit grows sublinearly)."""
from __future__ import annotations

from benchmarks.common import measure_cell, save_result

SIZES = [0, 1, 2, 4, 8, 16]


def run():
    rows = []
    base = None
    for s in SIZES:
        r = measure_cell("llama3-70b", "conversation", cache_tb=s,
                         rate=1.5, ci=124.0)
        if s == 0:
            base = float(r.ttft.mean())
        rows.append({"cache_tb": s, "avg_ttft": float(r.ttft.mean()),
                     "hit_rate": r.token_hit_rate,
                     "speedup": base / max(float(r.ttft.mean()), 1e-9)})
    save_result("fig6_cache_size", {"rows": rows})
    out = [(f"fig6/{r['cache_tb']}tb/hit_rate", r["hit_rate"],
            "token hit rate") for r in rows]
    out.append(("fig6/16tb/speedup", rows[-1]["speedup"], "vs no cache"))
    hits = [r["hit_rate"] for r in rows]
    out.append(("fig6/hit_rate_monotone",
                float(all(a <= b + 0.02 for a, b in zip(hits, hits[1:]))),
                "Takeaway 3 reproduced"))
    return out
