"""Fig 5: prefill/decode latency vs request rate, with/without cache.
Higher rates benefit more from caching (Takeaway 2)."""
from __future__ import annotations

from benchmarks.common import measure_cell, save_result

RATES = [0.4, 0.8, 1.2, 1.6]


def run():
    rows = []
    for rate in RATES:
        nc = measure_cell("llama3-70b", "conversation", cache_tb=0,
                          rate=rate, ci=124.0)
        c = measure_cell("llama3-70b", "conversation", cache_tb=16,
                         rate=rate, ci=124.0)
        rows.append({
            "rate": rate,
            "ttft_no_cache": float(nc.ttft.mean()),
            "ttft_cached": float(c.ttft.mean()),
            "tpot_no_cache": float(nc.tpot.mean()),
            "tpot_cached": float(c.tpot.mean()),
            "prefill_speedup": float(nc.ttft.mean() / max(c.ttft.mean(),
                                                          1e-9)),
            "decode_speedup": float(nc.tpot.mean() / max(c.tpot.mean(),
                                                         1e-9)),
        })
    save_result("fig5_request_rate", {"rows": rows})
    out = [(f"fig5/rate{r['rate']}/prefill_speedup", r["prefill_speedup"],
            "cache speedup") for r in rows]
    mono = all(a["prefill_speedup"] <= b["prefill_speedup"] * 1.15
               for a, b in zip(rows, rows[1:]))
    out.append(("fig5/speedup_grows_with_rate", float(
        rows[-1]["prefill_speedup"] > rows[0]["prefill_speedup"]),
        "Takeaway 2 reproduced"))
    out.append(("fig5/decode_speedup_at_peak", rows[-1]["decode_speedup"],
                "indirect decode benefit"))
    return out
