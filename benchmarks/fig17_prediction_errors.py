"""Fig 17: impact of predictor/profiler errors — GreenCache with real
predictors vs an oracle given groundtruth rate/CI. Paper: errors cost
≤ ~0.8 % of carbon savings on average. Also reports predictor MAPEs
(paper §6.5: load 4.3 %; CI 6.8-15.3 %)."""
from __future__ import annotations

import numpy as np

from repro.core.predictors import CIPredictor, LoadPredictor, mape
from repro.workloads.traces import azure_rate_trace, ci_trace

from benchmarks.common import GRIDS, save_result
from benchmarks.fig12_carbon_slo import run_one


def run():
    out = []
    payload = {}
    # predictor MAPEs
    hist = azure_rate_trace(1.6, days=3, seed=0, noise=0.04)
    truth = azure_rate_trace(1.6, days=1, seed=9, noise=0.04)
    load_mape = mape(LoadPredictor().fit(hist).predict(24), truth)
    out.append(("fig17/load_mape", load_mape, "paper: 0.043"))
    for grid in GRIDS:
        h = ci_trace(grid, days=6, seed=1)
        t = ci_trace(grid, days=1, seed=7)
        m = mape(CIPredictor().fit(h).predict(24), t)
        payload[f"ci_mape_{grid}"] = m
        out.append((f"fig17/ci_mape_{grid}", m, "paper: 0.068-0.153"))

    # end-to-end: predicted vs oracle decisions
    deltas = []
    for grid in ["FR", "CISO"]:
        pred = run_one("llama3-70b", "conversation", grid, "greencache")
        orac = run_one("llama3-70b", "conversation", grid, "oracle")
        d = (pred.carbon_per_request_g - orac.carbon_per_request_g) \
            / max(orac.carbon_per_request_g, 1e-12)
        deltas.append(d)
        out.append((f"fig17/{grid}/carbon_penalty_vs_oracle", d,
                    "prediction-error cost (paper: <1%)"))
        payload[f"penalty_{grid}"] = d
    payload["load_mape"] = load_mape
    save_result("fig17_prediction_errors", payload)
    out.append(("fig17/avg_penalty", float(np.mean(deltas)), "avg"))
    return out
