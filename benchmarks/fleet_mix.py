"""Heterogeneous fleet mix: homogeneous-new vs homogeneous-old vs
solver-chosen mix (GreenLLM-style old/new-generation tradeoff; no direct
paper figure).

Runs the same 24-hour Azure-shaped day through three fleet policies on two
grids — FR (clean: embodied carbon dominates, favouring already-amortized
old a100 servers) and TX (dirty: operational carbon dominates, favouring
efficient new h100 servers):

  * ``h100 x N``  — pinned homogeneous new-generation fleet
  * ``a100 x M``  — pinned homogeneous old-generation fleet (same nominal
                    capacity band)
  * ``solver``    — hourly (cache_tb, fleet) co-decision over every mix of
                    {a100, h100} up to MAX_REPLICAS (`enumerate_fleets`)

All three see the identical request stream (same workload seed and rate
trace); the cache size is solver-adapted (mode="greencache") in every run
so the only difference is the fleet policy. The derived column reports
whether the solver mix beats both pinned fleets on total gCO2e at equal
SLO attainment: the mix must meet the task's required attainment rho AND
Pareto-dominate each baseline (no worse SLO within EPS_SLO, strictly
lower carbon) — so a policy can never "win" carbon by under-provisioning
its way below the SLO bar.
"""
from __future__ import annotations


from repro.core.carbon import CarbonModel, fleet_capacity
from repro.core.controller import GreenCacheController
from repro.core.profiler import _slo_for
from repro.core.solver import enumerate_fleets
from repro.serving.perfmodel import SERVING_MODELS

from benchmarks.common import (RATE_GRID, SIZE_GRID, TASKS, WARMUP,
                               cap_requests, clip_day, profiler_kwargs,
                               save_result)

MODEL = "llama3-70b"
TASK = "conversation"
GRIDS = ["FR", "TX"]
MAX_REPLICAS = 3
HOMO_NEW = ("h100", "h100")                  # capacity 4.8 reference units
HOMO_OLD = ("a100", "a100", "a100")          # capacity 4.2 reference units
EPS_SLO = 0.02
PEAK_RATE = 1.25                             # per reference unit at peak


_PROF_CACHE = {}


def _profile():
    """Reference-platform profile measured on the *cluster-scale* workload
    (load_scale = the biggest candidate fleet's capacity): the widened
    working set gives realistic hit rates, so the solver's
    capacity-normalized SLO predictions match what the fleet simulation
    serves (``benchmarks.common.get_profile`` profiles the scale-1
    workload and would over-promise here)."""
    if "p" not in _PROF_CACHE:
        from repro.core.profiler import run_profiler
        scale = fleet_capacity(HOMO_NEW)
        t = TASKS[TASK]
        _PROF_CACHE["p"] = run_profiler(
            SERVING_MODELS[MODEL], TASK,
            lambda s: t["factory"](s, scale=scale), CarbonModel(),
            rates=RATE_GRID[(MODEL, TASK)], sizes_tb=SIZE_GRID[MODEL],
            warmup_prompts=WARMUP[TASK], policy=t["policy"],
            **profiler_kwargs())
    return _PROF_CACHE["p"]


def _day(grid: str, fleets, seed: int = 11):
    from repro.workloads.traces import azure_rate_trace, ci_trace

    prof = _profile()
    model = SERVING_MODELS[MODEL]
    carbon = CarbonModel()
    scale = fleet_capacity(HOMO_NEW)          # same stream for every policy
    wf = lambda s: TASKS[TASK]["factory"](s, scale=scale)   # noqa: E731
    from repro.core.plan import ResourcePlan
    if fleets and isinstance(fleets[0], str):
        fleets = [fleets]
    ctl = GreenCacheController(
        model, prof, carbon, TASK, mode="greencache",
        policy=TASKS[TASK]["policy"],
        plans=[ResourcePlan.single(None, fleet=tuple(f)) for f in fleets],
        warm_requests=cap_requests(8000, 400), seed=seed,
        max_requests_per_hour=cap_requests(900),
        # the scale-matched profile is already conservative about shared-
        # cache hit rates (a lone server at rate/cap sees the working set
        # spread thinner than N replicas sharing one store), so the
        # default +0.04 safety margin would double-hedge and buy idle
        # capacity
        rho_margin=0.0)
    rate_trace, cis = clip_day(azure_rate_trace(PEAK_RATE * scale, seed=3),
                               ci_trace(grid, seed=4))
    return ctl.run_day(wf, rate_trace, cis)


def run():
    out = []
    payload = {}
    mixes = enumerate_fleets(["a100", "h100"], MAX_REPLICAS)
    for grid in GRIDS:
        rows = {}
        for name, fleets in [("homo_new", list(HOMO_NEW)),
                             ("homo_old", list(HOMO_OLD)),
                             ("solver_mix", mixes)]:
            res = _day(grid, fleets)
            rows[name] = {
                "total_g": res.total_carbon_g,
                "carbon_per_req_g": res.carbon_per_request_g,
                "slo": res.slo_attainment,
                "avg_cache_tb": res.avg_cache_tb,
                "avg_capacity": res.avg_fleet_capacity,
                "hourly_fleets": [h.fleet for h in res.hours],
            }
            out.append((f"fleet_mix/{grid}/{name}/total_g",
                        res.total_carbon_g,
                        f"slo={res.slo_attainment:.3f} "
                        f"avg_cap={res.avg_fleet_capacity:.2f}"))
        mix, new, old = rows["solver_mix"], rows["homo_new"], rows["homo_old"]
        slo_floor = _slo_for(MODEL, TASK).rho - EPS_SLO
        # equal-SLO comparison via Pareto dominance: the mix must clear
        # the required attainment AND be no worse on SLO than each
        # baseline while strictly cheaper — beating an SLO-violating
        # baseline on carbon alone would not count, and a baseline cannot
        # "win" by under-provisioning below the bar
        beats = (mix["slo"] >= slo_floor
                 and all(mix["slo"] >= r["slo"] - EPS_SLO
                         and mix["total_g"] < r["total_g"]
                         for r in (new, old)))
        out.append((f"fleet_mix/{grid}/mix_beats_both", float(beats),
                    f"mix={mix['total_g']:.0f}g vs new={new['total_g']:.0f}g"
                    f" old={old['total_g']:.0f}g at slo>={slo_floor:.3f}"))
        payload[grid] = rows
    save_result("fleet_mix", payload)
    return out
