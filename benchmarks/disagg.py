"""Prefill/decode disaggregation: solver-chosen disaggregated
``ResourcePlan`` vs the best single-pool fleet (GreenLLM-style typed
old/new-generation asymmetry + DistServe-style pool split; no direct
paper figure).

Scenario: a decode-heavy chat stream (long model replies, ~1400 output
tokens — reasoning-trace-shaped traffic) in the clean FR grid. Decode
dominates token throughput, so fused fleets must provision whole fused
servers for it (the decode-overload TPOT penalty makes that capacity
real), while a disaggregated plan serves it from a *power-capped*,
already-amortized a100 decode pool and keeps a small compute-dense h100
prefill pool for TTFT. Both days see the identical request stream and
solver-adapted cache sizes; the only difference is the plan family:

  * ``single``  — hourly (cache, fleet) over every {a100,h100} mix up to
                  MAX_SINGLE replicas (``enumerate_fleets``) — i.e. the
                  *best* single-pool fleet the solver can find per hour.
  * ``disagg``  — hourly (cache, prefill fleet, decode fleet) over the
                  cross product of per-pool enumerations
                  (``enumerate_plans``).

The derived row reports whether the disaggregated day beats the
single-pool day on total gCO2e at ≥ equal SLO attainment (and above the
task's required rho — a plan cannot "win" by under-provisioning below
the SLO bar).

A second derived row is the plan-API regression anchor: a single-pool
all-l40 plan applied through ``ClusterEngine.apply`` must bit-reproduce
the pre-plan (PR-2) engine's hit/eviction/TTFT trajectories.
"""
from __future__ import annotations

import copy

import numpy as np

from repro.core.carbon import CarbonModel
from repro.core.controller import GreenCacheController
from repro.core.kvstore import KVStore
from repro.core.plan import ResourcePlan, enumerate_plans
from repro.core.policies import POLICIES
from repro.core.profiler import _slo_for, run_profiler
from repro.serving.cluster import ClusterEngine
from repro.serving.perfmodel import SERVING_MODELS

from benchmarks.common import (SMOKE, cap_requests, clip_day,
                               profiler_kwargs, save_result)

MODEL = "llama3-70b"
TASK = "conversation"
GRID = "FR"
MEAN_REPLY_TOKENS = 1600.0          # decode-heavy: reasoning-length outputs
PEAK_RATE = 3.6                     # req/s at the diurnal peak
SCALE = 4.8                         # working-set width (largest fleet cap)
RATES = [0.2, 0.45, 0.7, 0.9, 1.1]  # per capacity unit (envelope ~0.85)
SIZES = [0, 4, 8, 16]
MAX_SINGLE = 3
EPS_SLO = 0.02

SINGLE_FLEETS = ["a100", "h100"]
PREFILL_FLEETS = [("h100",), ("h100", "h100"), ("a100", "a100", "a100")]
DECODE_FLEETS = [("a100",), ("a100", "a100"), ("a100", "a100", "a100"),
                 ("h100",), ("a100", "h100")]

_CACHE = {}


def _workload(seed, scale=SCALE):
    from repro.workloads.conversations import ConversationWorkload
    return ConversationWorkload(seed=seed, load_scale=scale,
                                mean_reply_tokens=MEAN_REPLY_TOKENS)


def _profile():
    """Reference-platform profile of the decode-heavy stream at cluster
    scale (widened working set, realistic hit rates): the fused cells
    embed the decode-overload TPOT penalty, so single-pool feasibility is
    measured, and the per-metric SLO splits feed the disaggregated
    metrics."""
    if "p" not in _CACHE:
        _CACHE["p"] = run_profiler(
            SERVING_MODELS[MODEL], TASK, _workload, CarbonModel(),
            rates=RATES[:2] if SMOKE else RATES,
            sizes_tb=SIZES[:2] if SMOKE else SIZES,
            warmup_prompts=cap_requests(8000, 400),
            policy="lcs_chat", **profiler_kwargs())
    return _CACHE["p"]


def _day(plans, seed: int = 11):
    from repro.workloads.traces import azure_rate_trace, ci_trace

    ctl = GreenCacheController(
        SERVING_MODELS[MODEL], _profile(), CarbonModel(), TASK,
        mode="greencache", policy="lcs_chat", plans=plans,
        warm_requests=cap_requests(8000, 400), seed=seed,
        max_requests_per_hour=cap_requests(900),
        sizes_tb=SIZES[:2] if SMOKE else SIZES,
        # the scale-matched profile is already conservative about shared-
        # cache hit rates (see fleet_mix); skip the default safety margin
        rho_margin=0.0)
    rate_trace, cis = clip_day(azure_rate_trace(PEAK_RATE, seed=3),
                               ci_trace(GRID, seed=4))
    return ctl.run_day(_workload, rate_trace, cis)


def _bit_repro() -> bool:
    """All-l40 single-pool plan through ``apply`` vs the pre-plan untyped
    engine: hit/eviction stats and the TTFT sequence must be bit-equal."""
    from repro.workloads.traces import make_poisson_arrivals

    m = SERVING_MODELS[MODEL]
    cm = CarbonModel()
    wl = _workload(5, scale=2.0)
    arr = make_poisson_arrivals(np.full(24, 1.6), seed=6,
                                max_requests=cap_requests(9000, 2000))
    reqs = [wl.sample(t) for t in arr]

    def run(engine):
        rs = [copy.copy(r) for r in reqs]
        engine.warm(rs[:4000])
        res = engine.run(rs[4000:], ci_fn=lambda t: 33.0, cache_tb=4.0)
        return res, engine.stores[0].stats

    legacy = ClusterEngine(m, KVStore(4e12, POLICIES["lcs_chat"],
                                      m.kv_bytes_per_token), cm,
                           n_replicas=2, router="cache_affinity")
    planned = ClusterEngine(m, KVStore(4e12, POLICIES["lcs_chat"],
                                       m.kv_bytes_per_token), cm,
                            n_replicas=2, router="cache_affinity")
    planned.apply(ResourcePlan.single(4.0, fleet=["l40", "l40"],
                                      router="cache_affinity"))
    r_legacy, s_legacy = run(legacy)
    r_plan, s_plan = run(planned)
    return bool(np.array_equal(r_legacy.ttft, r_plan.ttft)
                and s_legacy == s_plan
                and r_legacy.energy_kwh == r_plan.energy_kwh)


def run():
    from repro.core.solver import enumerate_fleets

    out = []
    single_plans = [ResourcePlan.single(None, fleet=f)
                    for f in enumerate_fleets(SINGLE_FLEETS, MAX_SINGLE)]
    disagg_plans = enumerate_plans(PREFILL_FLEETS, DECODE_FLEETS)

    payload = {}
    results = {}
    for name, plans in [("single", single_plans), ("disagg", disagg_plans)]:
        res = _day(plans)
        results[name] = res
        payload[name] = {
            "total_g": res.total_carbon_g,
            "carbon_per_req_g": res.carbon_per_request_g,
            "slo": res.slo_attainment,
            "avg_cache_tb": res.avg_cache_tb,
            "avg_capacity": res.avg_fleet_capacity,
            "hourly_plans": [h.plan for h in res.hours],
        }
        out.append((f"disagg/{GRID}/{name}/total_g", res.total_carbon_g,
                    f"slo={res.slo_attainment:.3f} "
                    f"avg_cap={res.avg_fleet_capacity:.2f}"))

    single, disagg = results["single"], results["disagg"]
    slo_floor = _slo_for(MODEL, TASK).rho - EPS_SLO
    beats = (disagg.slo_attainment >= slo_floor
             and disagg.slo_attainment >= single.slo_attainment - EPS_SLO
             and disagg.total_carbon_g < single.total_carbon_g)
    out.append((f"disagg/{GRID}/disagg_beats_best_single", float(beats),
                f"disagg={disagg.total_carbon_g:.0f}g vs "
                f"single={single.total_carbon_g:.0f}g at "
                f"slo>={slo_floor:.3f}"))

    repro_ok = _bit_repro()
    out.append(("disagg/plan_bit_reproduces_legacy_engine", float(repro_ok),
                "all-l40 plan via apply == untyped engine "
                "(ttft/hits/evictions)"))
    payload["disagg_beats_best_single"] = bool(beats)
    payload["plan_bit_repro"] = repro_ok
    save_result("disagg", payload)
    return out
