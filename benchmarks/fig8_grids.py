"""Fig 8: (a) carbon savings from a 16 TB cache across 12 grids (ratio < 1
means reduction); (b) savings over a day in the CISO grid as CI varies.
Paper anchors: FR ≈ +16.5 %, MISO ≈ −7.5 %."""
from __future__ import annotations


from repro.core.carbon import FIG8_GRIDS, GRID_CI
from repro.workloads.traces import ci_trace

from benchmarks.common import measure_cell, save_result


def run():
    rows = []
    # measure once per CI (engine sim is CI-independent in perf terms)
    nc = measure_cell("llama3-70b", "conversation", cache_tb=0, rate=1.5,
                      ci=1.0)
    c16 = measure_cell("llama3-70b", "conversation", cache_tb=16, rate=1.5,
                       ci=1.0)

    def carbon_at(res, ci):
        op = res.operational_g / 1.0 * ci          # op measured at CI=1
        return (op + res.embodied_cache_g + res.embodied_compute_g) \
            / max(res.num_requests, 1)

    for grid in FIG8_GRIDS:
        ci = GRID_CI[grid]
        ratio = carbon_at(c16, ci) / carbon_at(nc, ci)
        rows.append({"grid": grid, "ci": ci, "ratio_16tb": ratio})

    # (b) CISO day: hourly CI trace
    ciso = ci_trace("CISO", days=1, seed=0)
    day = [{"hour": h, "ci": float(ciso[h]),
            "ratio_16tb": carbon_at(c16, float(ciso[h]))
            / carbon_at(nc, float(ciso[h]))} for h in range(24)]
    save_result("fig8_grids", {"grids": rows, "ciso_day": day})

    out = [(f"fig8a/{r['grid']}/ratio", r["ratio_16tb"],
            f"CI={r['ci']:.0f}") for r in rows]
    fr = next(r for r in rows if r["grid"] == "FR")["ratio_16tb"]
    miso = next(r for r in rows if r["grid"] == "MISO")["ratio_16tb"]
    out.append(("fig8a/FR_increases_carbon", float(fr > 1.0),
                "paper: +16.5%"))
    out.append(("fig8a/MISO_decreases_carbon", float(miso < 1.0),
                "paper: -7.5%"))
    ratios = [d["ratio_16tb"] for d in day]
    out.append(("fig8b/ciso_daily_swing", max(ratios) - min(ratios),
                "cache benefit swings within a day"))
    return out
