"""Fig 15 ablation: adaptive caching with the original LRU policy
("LRU + Optimal") vs Full Cache at fixed request rates, ES-grid average CI.
Paper: up to 10.3 % (chat) / 6.6-9.9 % (docs) carbon savings."""
from __future__ import annotations

import numpy as np

from repro.core.carbon import GRID_CI
from repro.core.controller import GreenCacheController
from repro.serving.perfmodel import SERVING_MODELS

from benchmarks.common import (CARBON, TASKS, WARMUP, cap_requests,
                               clip_day, get_profile, save_result,
                               task_name_for_slo)


def run():
    m = SERVING_MODELS["llama3-70b"]
    rows = []
    for task, rates_ in [("conversation", [0.6, 1.0, 1.4]),
                         ("doc_a04", [0.15, 0.3, 0.5])]:
        prof = get_profile("llama3-70b", task)
        for rate in rates_:
            flat, cis = clip_day(np.full(12, rate),
                                 np.full(12, GRID_CI["ES"]))
            res = {}
            for mode, policy in [("full", TASKS[task]["policy"]),
                                 ("lru_optimal", "lru"),
                                 ("greencache", TASKS[task]["policy"])]:
                ctl = GreenCacheController(
                    m, prof, CARBON, task_name_for_slo(task), mode="full"
                    if mode == "full" else "greencache", policy=policy,
                    warm_requests=WARMUP[task],
                    max_requests_per_hour=cap_requests(1000))
                r = ctl.run_day(TASKS[task]["factory"], flat, cis)
                res[mode] = r.carbon_per_request_g
            rows.append({
                "task": task, "rate": rate,
                "carbon_full": res["full"],
                "carbon_lru_optimal": res["lru_optimal"],
                "carbon_greencache": res["greencache"],
                "saving_lru_optimal": 1 - res["lru_optimal"] / res["full"],
                "saving_greencache": 1 - res["greencache"] / res["full"],
            })
    save_result("fig15_ablation_adaptive", {"rows": rows})
    out = []
    for r in rows:
        out.append((f"fig15/{r['task']}/rate{r['rate']}/adaptive_lru_saving",
                    r["saving_lru_optimal"], "adaptive sizing alone"))
        out.append((f"fig15/{r['task']}/rate{r['rate']}/greencache_saving",
                    r["saving_greencache"], "adaptive + LCS"))
    return out
