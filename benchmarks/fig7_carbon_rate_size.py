"""Fig 7: per-request carbon (a) vs request rate in the ES grid, and
(b) vs cache size across grid average CIs (Takeaways 4-5)."""
from __future__ import annotations

from repro.core.carbon import GRID_CI

from benchmarks.common import measure_cell, save_result


def run():
    # (a) rate sweep, ES grid, 16 TB vs none
    rate_rows = []
    for rate in [0.4, 0.8, 1.2, 1.6]:
        nc = measure_cell("llama3-70b", "conversation", cache_tb=0,
                          rate=rate, ci=GRID_CI["ES"])
        c = measure_cell("llama3-70b", "conversation", cache_tb=16,
                         rate=rate, ci=GRID_CI["ES"])
        rate_rows.append({"rate": rate,
                          "carbon_no_cache": nc.carbon_per_request_g,
                          "carbon_cached": c.carbon_per_request_g,
                          "ratio": c.carbon_per_request_g
                          / nc.carbon_per_request_g})
    # (b) size sweep × 4 grids
    size_rows = []
    for grid in ["FR", "FI", "ES", "CISO"]:
        for s in [0, 1, 4, 8, 16]:
            r = measure_cell("llama3-70b", "conversation", cache_tb=s,
                             rate=1.5, ci=GRID_CI[grid])
            size_rows.append({"grid": grid, "cache_tb": s,
                              "carbon_g": r.carbon_per_request_g,
                              "operational_g": r.operational_g
                              / max(r.num_requests, 1),
                              "embodied_cache_g": r.embodied_cache_g
                              / max(r.num_requests, 1)})
    save_result("fig7_carbon_rate_size", {"rate_rows": rate_rows,
                                          "size_rows": size_rows})
    out = []
    for r in rate_rows:
        out.append((f"fig7a/rate{r['rate']}/cached_over_nocache",
                    r["ratio"], "ES grid"))
    out.append(("fig7a/savings_grow_with_rate",
                float(rate_rows[-1]["ratio"] < rate_rows[0]["ratio"]),
                "Takeaway 4 reproduced"))
    by_grid = {}
    for r in size_rows:
        if r["cache_tb"] in (0, 16):
            by_grid.setdefault(r["grid"], {})[r["cache_tb"]] = r["carbon_g"]
    for g, d in by_grid.items():
        out.append((f"fig7b/{g}/16tb_ratio", d[16] / d[0],
                    "vs no-cache at grid-average CI"))
    return out
