"""Fig 11: profiler heatmaps — TTFT, TPOT and carbon savings over
(request rate × cache size) for both tasks (ES grid)."""
from __future__ import annotations

from repro.core.carbon import GRID_CI

from benchmarks.common import CARBON, get_profile, save_result


def run():
    out = []
    payload = {}
    for task in ["conversation", "doc_a04"]:
        prof = get_profile("llama3-70b", task)
        grid = []
        base = {}
        for r in prof.rates:
            base[r] = prof.cells[(r, 0)].carbon_per_req_g(GRID_CI["ES"],
                                                          CARBON)
        for (r, s), cell in sorted(prof.cells.items()):
            saving = base[r] / max(
                cell.carbon_per_req_g(GRID_CI["ES"], CARBON), 1e-12)
            grid.append({"rate": r, "cache_tb": s,
                         "avg_ttft": cell.avg_ttft,
                         "avg_tpot": cell.avg_tpot,
                         "slo_frac": cell.slo_frac,
                         "carbon_saving_ratio": saving})
        payload[task] = grid
        best = max(grid, key=lambda g: g["carbon_saving_ratio"])
        out.append((f"fig11/{task}/max_carbon_saving_ratio",
                    best["carbon_saving_ratio"],
                    f"at rate={best['rate']} size={best['cache_tb']}TB"))
        hi_rate = max(prof.rates)
        big = [g for g in grid if g["rate"] == hi_rate]
        out.append((f"fig11/{task}/ttft_improves_with_size",
                    float(big[-1]["avg_ttft"] < big[0]["avg_ttft"]),
                    "larger cache -> lower TTFT at peak rate"))
    save_result("fig11_profile_heatmaps", payload)
    return out
