"""Flight-recorder cost: tracing-off bit-identity + tracing-on overhead.

Runs the same simulated day through ``GreenCacheController.run_day``
with the recorder detached (the default) and attached
(``trace=True, metrics=True``) across every engine family — flat
cluster, prefill/decode disaggregation, DRAM+SSD tiered storage, radix
prefix sharing, and two-region geo routing — and asserts the
observability contract of PR 10:

  1. ``bit_identical``: the traced day reproduces the untraced day's
     per-hour carbon/SLO/hit-rate/latency numbers bit-exactly (every
     recording branch is gated on ``recorder is not None``; attaching
     the recorder must only *observe*);
  2. ``overhead_ratio``: wall-clock of the traced day over the untraced
     day (min over ``REPS`` runs each) stays within the CI bound
     (≤ 1.10 enforced by ``tools/check_perf.py`` against
     ``benchmarks/baselines/BENCH_trace_baseline.json``).

Writes ``experiments/results/BENCH_trace.json``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.controller import GreenCacheController
from repro.core.profiler import Profile, ProfileCell
from repro.serving.perfmodel import SERVING_MODELS
from repro.serving.regions import Region
from repro.workloads.conversations import ConversationWorkload
from repro.workloads.traces import azure_rate_trace, ci_trace

from benchmarks.common import CARBON, SMOKE, clip_day, save_result

REPS = 2 if SMOKE else 3
HOURS = 4 if SMOKE else 8
MAX_REQS = 120 if SMOKE else 240


def synth_profile(sizes=(0, 2, 4), rates=(0.2, 0.5, 1.0, 1.5, 2.0)):
    """Deterministic synthetic profile — overhead must be measured on a
    fixed instance, not on profiling noise."""
    prof = Profile("llama3-70b", "conversation",
                   rates=list(rates), sizes=list(sizes))
    for r in rates:
        for s in sizes:
            slo = float(np.clip(1.1 - 0.25 * r + 0.02 * s, 0.0, 1.0))
            prof.cells[(r, s)] = ProfileCell(
                rate=r, cache_tb=s, avg_ttft=0.5 + 0.5 * r, p90_ttft=1 + r,
                avg_tpot=0.05, p90_tpot=0.08, slo_frac=slo,
                hit_rate=min(0.1 * s, 0.8),
                energy_per_req_kwh=2e-4 * (1 + 1 / max(r, 0.1)),
                duration_per_req_s=1.0 / max(r, 0.1), avg_power_w=800.0,
                slo_ttft_frac=min(slo * 1.05, 1.0),
                slo_tpot_frac=min(slo * 1.1, 1.0), avg_out_tokens=400.0)
    return prof


GEO_REGIONS = [Region.make("west", cis=[10.0, 500.0] * 12,
                           rtt_ms={"na": 10.0, "eu": 120.0}),
               Region.make("east", cis=[500.0, 10.0] * 12,
                           rtt_ms={"na": 120.0, "eu": 10.0})]

# engine family -> (controller kwargs, run_day kwargs)
CONFIGS = {
    "cluster": (dict(plans=["cache=auto fleet=l40:2"]), {}),
    "disagg": (dict(plans=["cache=auto prefill=l40:1 decode=l40:2"]), {}),
    "tiered": (dict(storage=["dram:0.25tb+nvme_gen4:4tb"]), {}),
    "radix": (dict(prefix_caching=True), {}),
    "geo": (dict(plans=["cache=auto fleet=l40:2"]),
            dict(regions=GEO_REGIONS, geo="green")),
}


def day_kwargs(name):
    prefix = name == "radix"
    wf = lambda s: ConversationWorkload(seed=s, prefix=prefix)
    rates, cis = clip_day(azure_rate_trace(1.5, seed=3),
                          ci_trace("FR", seed=4), hours=HOURS)
    return wf, rates[:HOURS], cis[:HOURS]


def make_controller(name, *, trace):
    ckw, _ = CONFIGS[name]
    return GreenCacheController(
        SERVING_MODELS["llama3-70b"], synth_profile(), CARBON,
        "conversation", mode="greencache", policy="lcs_chat",
        warm_requests=400, max_requests_per_hour=MAX_REQS, seed=7,
        trace=trace, metrics=trace, **ckw)


def fingerprint(res):
    return [(h.carbon_g, h.operational_g, h.slo_frac, h.hit_rate,
             h.num_requests, h.p95_ttft, h.p99_tpot) for h in res.hours]


def run_config(name):
    _, rkw = CONFIGS[name]
    wf, rates, cis = day_kwargs(name)
    results, times = {}, {}
    for traced in (False, True):
        best, res = float("inf"), None
        for _ in range(REPS):
            ctl = make_controller(name, trace=traced)
            t0 = time.time()
            res = ctl.run_day(wf, rates, cis, **rkw)
            best = min(best, time.time() - t0)
        results[traced], times[traced] = res, best
        if traced:
            spans = ctl.trace.n
    ok = fingerprint(results[False]) == fingerprint(results[True])
    ratio = times[True] / max(times[False], 1e-9)
    return {"bit_identical": bool(ok), "overhead_ratio": float(ratio),
            "t_off_s": times[False], "t_on_s": times[True],
            "spans": int(spans),
            "requests": int(sum(h.num_requests
                                for h in results[False].hours))}


def run():
    payload = {"smoke": SMOKE, "hours": HOURS, "reps": REPS,
               "configs": {}}
    rows = []
    for name in CONFIGS:
        c = payload["configs"][name] = run_config(name)
        rows += [
            (f"tracing_overhead/{name}_bit_identical",
             1.0 if c["bit_identical"] else float("nan"),
             "traced day == untraced day per-hour numbers"),
            (f"tracing_overhead/{name}_overhead_ratio",
             c["overhead_ratio"],
             f"{c['spans']} spans, off {c['t_off_s']:.2f}s / "
             f"on {c['t_on_s']:.2f}s (CI bound 1.10)"),
        ]
    save_result("BENCH_trace", payload)
    return rows


if __name__ == "__main__":
    import sys
    nan = 0
    for name, value, derived in run():
        if value != value:
            nan += 1
            derived = f"NaN! {derived}"
        print(f"{name},{value:.6g},{derived}")
    sys.exit(1 if nan else 0)
