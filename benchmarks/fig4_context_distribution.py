"""Fig 4: context-length distributions of the two tasks.
Paper: 77.2 % of ShareGPT prompts have >1000 context tokens; TriviaQA docs
average 5880 tokens."""
from __future__ import annotations

import numpy as np

from repro.workloads import sample_many
from repro.workloads.conversations import ConversationWorkload
from repro.workloads.documents import DocumentWorkload

from benchmarks.common import save_result


def run():
    wl = ConversationWorkload(seed=0)
    reqs = sample_many(wl, np.arange(12000, dtype=float))
    ctx = np.array([r.context_tokens for r in reqs])
    frac_1k = float((ctx > 1000).mean())

    dl = DocumentWorkload(seed=0)
    doc_mean = float(np.mean(dl.doc_len))

    save_result("fig4_context_distribution", {
        "sharegpt_frac_ctx_gt_1000": frac_1k,
        "sharegpt_mean_context": float(ctx.mean()),
        "triviaqa_mean_doc_tokens": doc_mean,
        "sharegpt_percentiles": {p: float(np.percentile(ctx, p))
                                 for p in (10, 50, 90, 99)},
    })
    return [
        ("fig4/sharegpt_frac_ctx_gt_1000", frac_1k, "paper: 0.772"),
        ("fig4/triviaqa_mean_doc_tokens", doc_mean, "paper: 5880"),
    ]
