"""Benchmark harness — one module per paper table/figure.
Prints ``name,value,derived`` CSV rows (value column doubles as
us_per_call for the *_bench_time rows) and saves JSON payloads under
experiments/results/.

    PYTHONPATH=src python -m benchmarks.run [--only fig8,table3] [--smoke]

``--smoke`` (CI's bit-rot guard) sets GREENCACHE_SMOKE=1 before any
benchmark import: ``benchmarks.common`` shrinks its grids/traces/warmups
to a minutes-scale run, and the harness fails on any NaN value — so a
benchmark that silently stops producing finite carbon totals is caught
before review, not after.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

MODULES = [
    "fig3_context_length",
    "fig4_context_distribution",
    "fig5_request_rate",
    "fig6_cache_size",
    "fig7_carbon_rate_size",
    "fig8_grids",
    "fig11_profile_heatmaps",
    "fig12_carbon_slo",
    "table3_hit_rate",
    "fig15_ablation_adaptive",
    "fig16_solver_overhead",
    "fig17_prediction_errors",
    "fig18_resize_interval",
    "fig19_ssd_lifetime",
    "fig20_ssd_embodied",
    "cluster_scaling",
    "solver_scaling",
    "fleet_mix",
    "disagg",
    "transitions",
    "scenarios",
    "storage_tiers",
    "prefix_sharing",
    "georouting",
    "tracing_overhead",
    "roofline_report",
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-trace smoke run: shrink every grid/trace "
                         "(benchmarks.common.SMOKE) and fail on NaN "
                         "values")
    args = ap.parse_args()
    if args.smoke:
        # must land in the environment before benchmarks.common is
        # imported (module grids are frozen at import time)
        os.environ["GREENCACHE_SMOKE"] = "1"
    selected = [m for m in MODULES
                if not args.only or any(s in m
                                        for s in args.only.split(","))]
    print("name,value,derived")
    failures = 0
    nan_rows = 0
    for name in selected:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name}/ERROR,nan,{type(e).__name__}: {str(e)[:120]}")
            failures += 1
            continue
        dt = time.time() - t0
        for metric, value, derived in rows:
            if value != value:              # NaN: broken carbon totals
                nan_rows += 1
                derived = f"NaN! {derived}"
            print(f"{metric},{value:.6g},{derived}")
        print(f"{name}/_bench_time,{dt * 1e6:.0f},us_per_call "
              f"(whole benchmark)")
        sys.stdout.flush()
    if args.smoke and nan_rows:
        print(f"SMOKE FAIL: {nan_rows} NaN value(s)", file=sys.stderr)
    return 1 if failures or (args.smoke and nan_rows) else 0


if __name__ == "__main__":
    raise SystemExit(main())
