"""Fig 3: latency and speedup from caching under different context lengths.
Cache hits eliminate prefill for the cached context; speedup grows with
context length (Takeaway 1)."""
from __future__ import annotations

from repro.serving.perfmodel import SERVING_MODELS

from benchmarks.common import save_result

CONTEXT_LENGTHS = [512, 1024, 2048, 4096, 8192]
NEW_TOKENS = 64


def run():
    m = SERVING_MODELS["llama3-70b"]
    rows = []
    for ctx in CONTEXT_LENGTHS:
        t_nc = m.prefill_time(ctx + NEW_TOKENS, 0)
        t_c = m.prefill_time(NEW_TOKENS, ctx)
        rows.append({"context_tokens": ctx,
                     "prefill_no_cache_s": t_nc,
                     "prefill_cached_s": t_c,
                     "speedup": t_nc / t_c})
    save_result("fig3_context_length", {"rows": rows})
    out = []
    for r in rows:
        out.append((f"fig3/ctx{r['context_tokens']}/speedup",
                    r["speedup"], "prefill speedup from cache hit"))
    # monotonicity check (Takeaway 1)
    mono = all(a["speedup"] <= b["speedup"]
               for a, b in zip(rows, rows[1:]))
    out.append(("fig3/speedup_monotone_in_context", float(mono),
                "Takeaway 1 reproduced"))
    return out
