"""Planning-engine scaling: solve time vs option-space size, 1x-10x.

Times the vectorized, Pareto-pruned transition-aware day solve
(``solve_cluster_schedule`` defaults) against the pre-PR path (scalar
per-cell table closures + the per-bucket-loop reference DP) on the same
instances, sweeping the candidate-plan count to 10x today's fleets.
Standing bit-repro rows assert the exactness contract: with beam off,
the pruned vectorized solve returns plans/objectives bit-identical to
the exhaustive reference path at every scale.

Writes the solve-time / engine-throughput numbers to
``experiments/results/BENCH_perf.json`` — the artifact the CI
``perf-smoke`` job records and regression-checks (>2x vs the committed
``benchmarks/baselines/BENCH_perf_baseline.json`` fails the job).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.plan import ResourcePlan, TransitionConfig
from repro.core.profiler import Profile, ProfileCell
from repro.core import solver as solver_mod
from repro.core.solver import PlannerCache, solve_cluster_schedule
from repro.serving.perfmodel import SERVING_MODELS, SLOS

from benchmarks.common import CARBON, SMOKE, save_result

SCALES = [1, 2, 4, 10] if not SMOKE else [1, 2]
HOURS = 24 if not SMOKE else 6
SIZES = [0, 1, 2, 4, 8, 12, 16]
TYPES = ["l40", "a100", "h100", "tpu_v5e"]


def scaling_profile(rates=(0.2, 0.5, 1.0, 1.6, 2.4), sizes=SIZES):
    """Deterministic synthetic profile — the perf sweep must not depend
    on profiling noise, only on instance shape."""
    prof = Profile("llama3-70b", "conversation",
                   rates=list(rates), sizes=list(sizes))
    for r in rates:
        for s in sizes:
            slo = min(1.0, 0.35 + 0.03 * s
                      + 0.4 / max(r, 0.3) * (0.2 + 0.03 * s))
            prof.cells[(r, s)] = ProfileCell(
                rate=r, cache_tb=s, avg_ttft=1.0 + 0.2 * r, p90_ttft=2.0,
                avg_tpot=0.1, p90_tpot=0.15, slo_frac=slo,
                hit_rate=min(0.85, 0.05 * s),
                energy_per_req_kwh=2e-4 * (1.0 - 0.006 * s)
                * (1.0 + 0.05 * r),
                duration_per_req_s=1.0 / r, avg_power_w=950.0 + 40.0 * r,
                slo_ttft_frac=min(1.0, slo + 0.05),
                slo_tpot_frac=min(1.0, slo + 0.1),
                avg_out_tokens=210.0, avg_prompt_tokens=1600.0,
                write_bytes_per_req=6e7)
    return prof


def make_plans(mult: int):
    """Candidate fleets at ``mult``x today's count: every type at
    1..2*mult replicas (1x = 8 plans, 10x = 80; x 7 sizes = 56..560
    options in the transition DP)."""
    return [ResourcePlan.parse(f"serve={t}:{k}")
            for t in TYPES for k in range(1, 2 * mult + 1)]


def _reference_dp_shim(C, F, n, options, rho, t_start, E, S, e_init,
                       cis, min_dwell, dwell_offset, lock0=None,
                       buckets=400, prune=False, beam_width=None,
                       class_keys=None):
    return solver_mod._solve_dp_transition_reference(
        C, F, n, options, rho, t_start, E, S, e_init, cis, min_dwell,
        dwell_offset, lock0=lock0, buckets=buckets)


def _plain_reference_shim(C, F, n, sizes, rho, t_start, buckets=400,
                          prune=False, beam_width=None):
    return solver_mod._solve_dp_reference(C, F, n, sizes, rho, t_start,
                                          buckets=buckets)


class pre_pr_solver:
    """Context manager that rewires ``solve_cluster_schedule`` onto the
    pre-PR path: scalar table closures + per-bucket reference DPs."""

    def __enter__(self):
        self._dp = solver_mod._solve_dp
        self._tdp = solver_mod._solve_dp_transition
        self._tm = solver_mod._transition_matrices
        solver_mod._solve_dp = _plain_reference_shim
        solver_mod._solve_dp_transition = _reference_dp_shim
        solver_mod._transition_matrices = \
            solver_mod._transition_matrices_reference
        return self

    def __exit__(self, *a):
        solver_mod._solve_dp = self._dp
        solver_mod._solve_dp_transition = self._tdp
        solver_mod._transition_matrices = self._tm


def day_solve(prof, plans, rates, cis, slo, *, vectorize=True, prune=True,
              beam_width=None, cache=None):
    return solve_cluster_schedule(
        prof, rates, cis, slo, CARBON, sizes_tb=SIZES, plans=plans,
        model=SERVING_MODELS["llama3-70b"], use_ilp=False,
        transitions=TransitionConfig(), min_dwell_hours=2,
        initial_plan=plans[0], vectorize=vectorize, prune=prune,
        beam_width=beam_width, solver_cache=cache)


def same_result(a, b) -> bool:
    return (a.sizes_tb == b.sizes_tb and a.plans == b.plans
            and a.objective_g == b.objective_g
            and a.feasible == b.feasible
            and a.transition_g == b.transition_g)


def run():
    prof = scaling_profile()
    slo = SLOS[("llama3-70b", "chat")]
    rng = np.random.default_rng(11)
    rates = list(rng.uniform(0.3, 2.2, HOURS))
    cis = list(rng.uniform(30.0, 500.0, HOURS))

    rows = []
    payload = {"smoke": SMOKE, "hours": HOURS, "scales": {}}
    exact_ok = True
    for mult in SCALES:
        plans = make_plans(mult)
        n_options = len(plans) * len(SIZES)

        new = day_solve(prof, plans, rates, cis, slo)
        t0 = time.time()
        new = day_solve(prof, plans, rates, cis, slo)
        t_new = time.time() - t0

        # exactness contract: pruned vectorized == exhaustive reference
        exhaustive = day_solve(prof, plans, rates, cis, slo,
                               prune=False)
        with pre_pr_solver():
            t0 = time.time()
            old = day_solve(prof, plans, rates, cis, slo,
                            vectorize=False, prune=False)
            t_old = time.time() - t0
        ok = same_result(new, exhaustive) and same_result(new, old)
        exact_ok = exact_ok and ok

        beam = day_solve(prof, plans, rates, cis, slo, beam_width=4)
        bound = beam.beam_bound_g if beam.beam_bound_g is not None \
            else float("nan")

        payload["scales"][str(mult)] = {
            "n_options": n_options,
            "solve_s_new": t_new,
            "solve_s_pre_pr": t_old,
            "speedup": t_old / max(t_new, 1e-9),
            "options_per_s": n_options * HOURS / max(t_new, 1e-9),
            "bit_identical": bool(ok),
            "beam_bound_g": float(bound),
            "beam_gap_g": float(beam.objective_g - new.objective_g),
        }
        rows += [
            (f"solver_scaling/{mult}x_solve_s", t_new,
             f"{n_options} options, {HOURS} h, transition-aware"),
            (f"solver_scaling/{mult}x_speedup_vs_pre_pr",
             t_old / max(t_new, 1e-9), f"pre-PR {t_old:.2f}s"),
            (f"solver_scaling/{mult}x_bit_identical",
             1.0 if ok else float("nan"),
             "pruned == exhaustive == pre-PR plans/objective"),
        ]

    top = payload["scales"][str(SCALES[-1])]
    rows += [
        ("solver_scaling/top_scale_solve_s", top["solve_s_new"],
         f"target < 1 s at {SCALES[-1]}x"),
        ("solver_scaling/exactness",
         1.0 if exact_ok else float("nan"),
         "standing bit-repro row (NaN fails --smoke)"),
    ]

    # controller-style reuse: PlannerCache amortizes the transition
    # matrices across re-solves of the same candidate set (MPC cadence)
    plans = make_plans(SCALES[-1])
    cache = PlannerCache()
    day_solve(prof, plans, rates, cis, slo, cache=cache)
    t0 = time.time()
    day_solve(prof, plans, rates, cis, slo, cache=cache)
    t_cached = time.time() - t0
    payload["resolve_s_cached"] = t_cached
    rows.append(("solver_scaling/cached_resolve_s", t_cached,
                 "PlannerCache hit (hourly re-solve cost)"))

    save_result("BENCH_perf", payload)
    return rows


if __name__ == "__main__":
    import argparse
    import sys
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        import os
        os.environ["GREENCACHE_SMOKE"] = "1"
        for m in list(sys.modules):
            if m.startswith("benchmarks"):
                del sys.modules[m]
    # re-import under the (possibly) new smoke setting
    from benchmarks import solver_scaling as mod
    nan = 0
    for name, value, derived in mod.run():
        if value != value:
            nan += 1
            derived = f"NaN! {derived}"
        print(f"{name},{value:.6g},{derived}")
    sys.exit(1 if nan else 0)
