"""Table 3: token hit rates of FIFO / LRU / LCS across cache sizes and
tasks. Paper anchors (ShareGPT, LCS): 1TB 0.08, 2TB 0.17, 16TB 0.71; LCS
outperforms LRU/FIFO especially at small sizes."""
from __future__ import annotations

from benchmarks.common import measure_cell, save_result

SIZES = [1, 2, 4, 8, 16]
POLS = {"fifo": "fifo", "lru": "lru"}
TASK_POLS = {"conversation": "lcs_chat", "doc_a04": "lcs_doc",
             "doc_a07": "lcs_doc"}
RATE = {"conversation": 1.5, "doc_a04": 0.4, "doc_a07": 0.4}


def run():
    table = {}
    out = []
    for task in ["conversation", "doc_a04", "doc_a07"]:
        for pol_name in ["fifo", "lru", "lcs"]:
            policy = TASK_POLS[task] if pol_name == "lcs" else pol_name
            for size in SIZES:
                r = measure_cell("llama3-70b", task, cache_tb=size,
                                 rate=RATE[task], ci=0.0, policy=policy,
                                 n_seconds=300)
                table[f"{task}/{pol_name}/{size}"] = r.token_hit_rate
    save_result("table3_hit_rate", table)
    for size in SIZES:
        lcs = table[f"conversation/lcs/{size}"]
        lru = table[f"conversation/lru/{size}"]
        fifo = table[f"conversation/fifo/{size}"]
        out.append((f"table3/chat/{size}tb/lcs", lcs,
                    f"lru={lru:.2f} fifo={fifo:.2f}"))
    wins = sum(1 for k, v in table.items()
               if "/lcs/" in k and v + 1e-9 >=
               table[k.replace("/lcs/", "/lru/")] - 0.02)
    total = sum(1 for k in table if "/lcs/" in k)
    out.append(("table3/lcs_geq_lru_fraction", wins / total,
                "LCS >= LRU in most cells (paper: vast majority)"))
    return out
