"""Wear-aware tiered storage: solver-chosen DRAM+NVMe tiering vs the
best flat-SSD plan, and endurance-limited cache sizing (paper Figs.
19-20 made decision-relevant; no direct paper figure for the tiering —
EcoServe 2502.05043 motivates provisioning embodied amortization
against real device lifetime).

Three claims, three parts:

* **Tiered beats flat (FR, skewed doc traffic, seeds 11/23)** — the
  solver co-decides (fleet, storage spec) hourly over {l40:2, l40:3} ×
  {flat NVMe, DRAM-mirror + NVMe} candidates.  Zipf-skewed document
  reads concentrate hit bytes on a small working set, so a 1 TB DRAM
  mirror strips the SSD KV-load from most hits; queue wait compounds
  service time (Takeaway 2), so near saturation the two-replica fleet
  meets the SLO only with the mirror — the flat day must run the third
  replica (a whole server's power + embodied) through the peak to buy
  the same attainment.  Derived row: tiered day total gCO2e <= flat day
  at equal-or-better SLO.
* **Wear changes cache sizing (churn-heavy QLC trace)** — weak-skew
  document traffic (zipf 0.4) churns the cache hard; on a QLC device
  (0.3 DWPD, WAF 4) the wear clock burns the embodied budget in months
  whatever the allocation, so caching stops paying.  Derived row: the
  wear-aware solver's hourly sizes differ from the calendar-lifetime
  baseline's.
* **Default-device bit-repro** — a greencache day whose storage
  candidates are default-``nvme_gen4`` flat specs with the wear clock
  off must bit-reproduce the PR-4 flat path's hour records (carbon,
  sizes, SLO, hit rates) — the typed subsystem is a strict superset of
  the legacy model.
"""
from __future__ import annotations


from repro.core.carbon import CarbonModel
from repro.core.controller import GreenCacheController
from repro.core.plan import ResourcePlan
from repro.core.profiler import run_profiler
from repro.core.storage import StorageSpec
from repro.serving.perfmodel import SERVING_MODELS

from benchmarks.common import (SMOKE, cap_requests, clip_day,
                               profiler_kwargs, save_result)

MODEL = "llama3-70b"
GRID = "FR"
EPS_SLO = 0.02

# ---- part A: tiered vs flat (skewed docs, fleet x storage) ---- #
ZIPF = 1.0                          # strong skew: hot working set
SCALE = 3.0                         # corpus width (widest fleet capacity)
PEAK_RATE = 4.4                     # cluster req/s at the diurnal peak
RATES = [0.4, 0.9, 1.4, 1.9, 2.4]   # per reference-server profile grid
SIZES = [2, 4, 8, 16]               # cold/flat allocations (TB)
HOT_TB = 1.0                        # DRAM mirror candidate size
PROFILE_SIZES = [0, HOT_TB, 2, 4, 8, 16]
FLEETS = ["l40:2", "l40:3"]

FLAT_SPECS = [StorageSpec.flat(s) for s in SIZES]
TIERED_SPECS = [StorageSpec.tiered(h, s) for s in SIZES
                for h in (0.0, HOT_TB)]

# ---- part B: wear-driven sizing (churn-heavy QLC trace) ---- #
CHURN_ZIPF = 0.4
CHURN_RATES = [0.1, 0.25, 0.45, 0.65]
CHURN_SIZES = [0, 1, 2, 4, 8]
CHURN_PEAK = 0.55
QLC_SPECS = [StorageSpec.flat(s, "qlc_ssd") for s in CHURN_SIZES]

_CACHE = {}


def _workload(seed, scale=SCALE, zipf=ZIPF):
    from repro.workloads.documents import DocumentWorkload
    return DocumentWorkload(seed=seed, zipf_alpha=zipf, load_scale=scale)


def _profile(kind: str):
    if kind not in _CACHE:
        if kind == "skew":
            rates, sizes = RATES, PROFILE_SIZES
            wf = _workload
        else:
            rates, sizes = CHURN_RATES, CHURN_SIZES
            wf = lambda s: _workload(s, scale=1.0, zipf=CHURN_ZIPF)  # noqa: E731
        _CACHE[kind] = run_profiler(
            SERVING_MODELS[MODEL], "document", wf, CarbonModel(),
            rates=rates[:2] if SMOKE else rates,
            sizes_tb=sizes[:3] if SMOKE else sizes,
            warmup_prompts=cap_requests(8000, 400),
            policy="lcs_doc", **profiler_kwargs())
    return _CACHE[kind]


def _day(specs, *, seed=11, wear=True, plans=None, peak=PEAK_RATE,
         scale=SCALE, zipf=ZIPF, kind="skew", sizes=None):
    from repro.workloads.traces import azure_rate_trace, ci_trace

    ctl = GreenCacheController(
        SERVING_MODELS[MODEL], _profile(kind), CarbonModel(), "document",
        mode="greencache", policy="lcs_doc",
        plans=plans if plans is not None
        else [ResourcePlan.single(None, fleet=f) for f in FLEETS],
        warm_requests=cap_requests(8000, 400), seed=seed,
        max_requests_per_hour=cap_requests(1800),
        sizes_tb=sizes, rho_margin=0.0,
        storage=specs, wear_aware=wear)
    rate_trace, cis = clip_day(azure_rate_trace(peak, seed=3),
                               ci_trace(GRID, seed=4))
    return ctl.run_day(lambda s: _workload(s, scale=scale, zipf=zipf),
                       rate_trace, cis)


def _row(name, res):
    return (f"storage_tiers/{GRID}/{name}/total_g", res.total_carbon_g,
            f"slo={res.slo_attainment:.3f} avg_tb={res.avg_cache_tb:.1f} "
            f"churn={sum(h.written_gb for h in res.hours):.0f}GB")


def _same_records(a, b) -> bool:
    return len(a.hours) == len(b.hours) and all(
        ha.carbon_g == hb.carbon_g and ha.cache_tb == hb.cache_tb
        and ha.slo_frac == hb.slo_frac and ha.hit_rate == hb.hit_rate
        for ha, hb in zip(a.hours, b.hours))


def _bit_repro() -> bool:
    """Greencache day through the identical solver path: flat size grid
    (storage=None, the PR-4 configuration) vs default-device flat specs
    with the wear clock off — hour records must be bit-equal."""
    plans = [ResourcePlan.single(None, fleet=("a100",))]
    sizes = SIZES[:2] if SMOKE else SIZES
    legacy = _day(None, plans=plans, sizes=sizes, wear=False, peak=1.1,
                  scale=1.4)
    typed = _day([StorageSpec.flat(s) for s in sizes], plans=plans,
                 sizes=sizes, wear=False, peak=1.1, scale=1.4)
    return _same_records(legacy, typed)


def run():
    out = []
    seeds = [11] if SMOKE else [11, 23]
    payload = {"seeds": {}}
    wins = []
    for seed in seeds:
        flat = _day(FLAT_SPECS, seed=seed)
        tiered = _day(TIERED_SPECS, seed=seed)
        out.append(_row(f"seed{seed}/flat", flat))
        out.append(_row(f"seed{seed}/tiered", tiered))
        # SMOKE's 4-hour trace carries no peak, so both days pick the
        # same flat plan and differ only by float noise in the tiered
        # store's per-request KV-load summation — allow that noise band
        # there (the full run wins by ~2 %, well clear of it)
        eps_g = 0.002 * flat.total_carbon_g if SMOKE else 0.0
        wins.append(tiered.slo_attainment
                    >= flat.slo_attainment - EPS_SLO
                    and tiered.total_carbon_g
                    <= flat.total_carbon_g + eps_g)
        payload["seeds"][seed] = {
            k: {"total_g": r.total_carbon_g, "slo": r.slo_attainment,
                "avg_cache_tb": r.avg_cache_tb,
                "avg_capacity": r.avg_fleet_capacity,
                "written_gb": sum(h.written_gb for h in r.hours),
                "hourly_plans": [h.plan for h in r.hours]}
            for k, r in [("flat", flat), ("tiered", tiered)]}
    beats = all(wins)
    out.append((f"storage_tiers/{GRID}/tiered_beats_flat", float(beats),
                f"<= gCO2e at >= SLO-{EPS_SLO} on {len(wins)} seed(s)"))

    # part B: wear vs calendar sizing on the churn-heavy QLC trace
    churn_kw = dict(plans=[ResourcePlan.single(None, fleet=("l40",))],
                    peak=CHURN_PEAK, scale=1.0, zipf=CHURN_ZIPF,
                    kind="churn")
    wear = _day(QLC_SPECS, wear=True, **churn_kw)
    cal = _day(QLC_SPECS, wear=False, **churn_kw)
    sizes_differ = [h.cache_tb for h in wear.hours] \
        != [h.cache_tb for h in cal.hours]
    out.append(("storage_tiers/churn/wear/avg_tb", wear.avg_cache_tb,
                f"total_g={wear.total_carbon_g:.0f} "
                f"slo={wear.slo_attainment:.3f}"))
    out.append(("storage_tiers/churn/calendar/avg_tb", cal.avg_cache_tb,
                f"total_g={cal.total_carbon_g:.0f} "
                f"slo={cal.slo_attainment:.3f}"))
    out.append(("storage_tiers/churn/wear_changes_sizing",
                float(sizes_differ),
                "wear-aware hourly sizes != calendar baseline on QLC"))
    payload["churn"] = {
        "wear_sizes": [h.cache_tb for h in wear.hours],
        "calendar_sizes": [h.cache_tb for h in cal.hours],
        "wear_total_g": wear.total_carbon_g,
        "calendar_total_g": cal.total_carbon_g}

    repro_ok = _bit_repro()
    out.append(("storage_tiers/default_device_bit_repro", float(repro_ok),
                "flat nvme_gen4 specs (wear off) == PR-4 hour records"))
    payload["tiered_beats_flat"] = bool(beats)
    payload["wear_changes_sizing"] = bool(sizes_differ)
    payload["default_device_bit_repro"] = repro_ok
    save_result("storage_tiers", payload)
    return out
