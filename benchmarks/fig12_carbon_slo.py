"""Fig 12 + 13 + 14 (main evaluation): 24-hour serving under real-shaped
rate and CI traces — No-Cache vs Full-Cache vs GreenCache, 4 grids ×
{multi-turn chat, doc α=0.4, doc α=0.7} × {70B, 8B}.

Paper anchors: GreenCache vs Full-Cache average carbon reduction 12.6 %
(chat, 70B, 4-grid avg), 15.1 % in FR (up to 25.3 %); >90 % SLO attainment;
No-Cache violates SLO."""
from __future__ import annotations

import numpy as np

from repro.core.controller import GreenCacheController
from repro.serving.perfmodel import SERVING_MODELS
from repro.workloads.traces import azure_rate_trace, ci_trace

from benchmarks.common import (CARBON, GRIDS, RATE_GRID, TASKS, WARMUP,
                               cap_requests, clip_day, get_profile,
                               save_result, task_name_for_slo)

MODES = ["none", "full", "greencache"]
# compact cluster slice: co-decide (cache, replicas) at 3x load, FR grid
CLUSTER_REPLICAS = [1, 2, 3, 4]
CLUSTER_SCALE = 3.0


def run_one(model_name: str, task: str, grid: str, mode: str, seed=3,
            n_replicas=1, router=None):
    from repro.core.plan import ResourcePlan, normalize_replicas

    m = SERVING_MODELS[model_name]
    prof = get_profile(model_name, task)
    peak = RATE_GRID[(model_name, task)][-1]
    counts = normalize_replicas(n_replicas)
    scale = float(max(counts))
    rates, cis = clip_day(azure_rate_trace(peak * scale, seed=seed),
                          ci_trace(grid, seed=seed + 1))
    ctl = GreenCacheController(
        m, prof, CARBON, task_name_for_slo(task), mode=mode,
        policy=TASKS[task]["policy"], warm_requests=WARMUP[task],
        max_requests_per_hour=cap_requests(1500 * scale),
        plans=[ResourcePlan.single(None, n_replicas=k, router=router)
               for k in counts])
    res = ctl.run_day(lambda s: TASKS[task]["factory"](s, scale=scale),
                      rates, cis)
    return res


def run(models=("llama3-70b", "llama3-8b"),
        tasks=("conversation", "doc_a04", "doc_a07")):
    rows = []
    timelines = {}
    for model_name in models:
        for task in tasks:
            for grid in GRIDS:
                per_mode = {}
                for mode in MODES:
                    r = run_one(model_name, task, grid, mode)
                    per_mode[mode] = r
                    rows.append({
                        "model": model_name, "task": task, "grid": grid,
                        "mode": mode,
                        "carbon_per_req_g": r.carbon_per_request_g,
                        "slo": r.slo_attainment,
                        "avg_cache_tb": r.avg_cache_tb,
                        "p90_ttft_max": max(h.p90_ttft for h in r.hours),
                        "p90_tpot_max": max(h.p90_tpot for h in r.hours),
                    })
                key = f"{model_name}/{task}/{grid}"
                timelines[key] = {
                    mode: {
                        "cache_tb": [h.cache_tb for h in per_mode[mode].hours],
                        "carbon_g": [h.carbon_g for h in per_mode[mode].hours],
                        "p90_ttft": [h.p90_ttft for h in per_mode[mode].hours],
                        "p90_tpot": [h.p90_tpot for h in per_mode[mode].hours],
                        "hit_rate": [h.hit_rate for h in per_mode[mode].hours],
                        "rate": [h.rate for h in per_mode[mode].hours],
                        "ci": [h.ci for h in per_mode[mode].hours],
                    } for mode in MODES}
    save_result("fig12_carbon_slo", {"rows": rows})
    save_result("fig13_14_timelines", timelines)

    out = []
    for model_name in models:
        for task in tasks:
            reds = []
            for grid in GRIDS:
                gc = next(r for r in rows if r["model"] == model_name
                          and r["task"] == task and r["grid"] == grid
                          and r["mode"] == "greencache")
                fc = next(r for r in rows if r["model"] == model_name
                          and r["task"] == task and r["grid"] == grid
                          and r["mode"] == "full")
                red = 1 - gc["carbon_per_req_g"] / fc["carbon_per_req_g"]
                reds.append(red)
                out.append((f"fig12/{model_name}/{task}/{grid}/reduction_vs_full",
                            red, f"slo={gc['slo']:.3f} "
                            f"cache={gc['avg_cache_tb']:.1f}TB"))
            out.append((f"fig12/{model_name}/{task}/avg_reduction",
                        float(np.mean(reds)),
                        "paper 70B chat: 12.6% avg; FR 15.1%"))
    # SLO summary
    gc_slo = [r["slo"] for r in rows if r["mode"] == "greencache"]
    nc_slo = [r["slo"] for r in rows if r["mode"] == "none"]
    out.append(("fig13/greencache_min_slo", float(np.min(gc_slo)),
                "target >= 0.9"))
    out.append(("fig13/nocache_mean_slo", float(np.mean(nc_slo)),
                "no-cache violates SLO"))

    # cluster slice: hourly (cache, replicas) co-decision with affinity
    # routing vs a fixed max-replica fleet, 70B chat at 3x load in FR
    fixed = run_one("llama3-70b", "conversation", "FR", "full",
                    n_replicas=max(CLUSTER_REPLICAS),
                    router="cache_affinity")
    codec = run_one("llama3-70b", "conversation", "FR", "greencache",
                    n_replicas=CLUSTER_REPLICAS, router="cache_affinity")
    red = 1 - codec.carbon_per_request_g / fixed.carbon_per_request_g
    out.append(("fig12/cluster/codecide_reduction_vs_fixed_fleet", red,
                f"slo={codec.slo_attainment:.3f} "
                f"avg_replicas={codec.avg_replicas:.2f} "
                f"avg_cache={codec.avg_cache_tb:.1f}TB"))
    save_result("fig12_cluster", {
        "fixed_fleet": {"carbon_per_req_g": fixed.carbon_per_request_g,
                        "slo": fixed.slo_attainment},
        "codecide": {"carbon_per_req_g": codec.carbon_per_request_g,
                     "slo": codec.slo_attainment,
                     "replicas": [h.n_replicas for h in codec.hours],
                     "cache_tb": [h.cache_tb for h in codec.hours]}})
    return out
