"""Fig 20: sensitivity to SSD embodied carbon (30-90 kgCO2e/TB): higher
embodied carbon widens GreenCache's advantage (paper: up to 25 % at
90 kg/TB).

Like fig19, the sweep walks the storage *device registry*: each point is
the reference ``nvme_gen4`` device with a rescaled ``embodied_kg_per_tb``
projected through ``device_hardware_spec`` — zero-diff at the default
30 kg/TB device."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.carbon import CarbonModel, GRID_CI
from repro.core.controller import GreenCacheController
from repro.core.storage import (DEFAULT_DEVICE, STORAGE_DEVICES,
                                device_hardware_spec)
from repro.serving.perfmodel import SERVING_MODELS

from benchmarks.common import (TASKS, WARMUP, cap_requests, clip_day,
                               get_profile, save_result)

EMBODIED = [30.0, 60.0, 90.0]


def run():
    m = SERVING_MODELS["llama3-70b"]
    prof = get_profile("llama3-70b", "conversation")
    rows = []
    for kg in EMBODIED:
        dev = dataclasses.replace(STORAGE_DEVICES[DEFAULT_DEVICE],
                                  embodied_kg_per_tb=kg)
        cm = CarbonModel(hw=device_hardware_spec(dev))
        rates, cis = clip_day(np.full(12, 1.5),
                              np.full(12, GRID_CI["ES"]))
        res = {}
        for mode in ["full", "greencache"]:
            ctl = GreenCacheController(
                m, prof, cm, "conversation", mode=mode, policy="lcs_chat",
                warm_requests=WARMUP["conversation"],
                max_requests_per_hour=cap_requests(1000))
            res[mode] = ctl.run_day(TASKS["conversation"]["factory"],
                                    rates, cis).carbon_per_request_g
        rows.append({"kg_per_tb": kg,
                     "saving": 1 - res["greencache"] / res["full"]})
    save_result("fig20_ssd_embodied", {"rows": rows})
    out = [(f"fig20/{int(r['kg_per_tb'])}kg/saving", r["saving"],
            "GreenCache vs Full") for r in rows]
    out.append(("fig20/higher_embodied_more_saving",
                float(rows[-1]["saving"] >= rows[0]["saving"] - 0.02),
                "paper: up to 25% at 90kg/TB"))
    return out
