"""Shared benchmark infrastructure: cached profiles, workload factories,
and the standard experiment grid (paper §6.1)."""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict

import numpy as np

from repro.core.carbon import CarbonModel
from repro.core.plan import DEFAULT_BALANCE_EPS, ResourcePlan
from repro.core.policies import POLICIES
from repro.core.profiler import Profile, run_profiler
from repro.serving.cluster import make_cluster
from repro.serving.perfmodel import SERVING_MODELS
from repro.workloads.conversations import ConversationWorkload
from repro.workloads.documents import DocumentWorkload
from repro.workloads.traces import make_poisson_arrivals

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "results")
# smoke mode (``benchmarks.run --smoke`` / GREENCACHE_SMOKE=1): a
# minutes-scale bit-rot check — tiny grids, short traces, shrunken
# warmups.  The numbers are NOT meaningful; the CI job only asserts the
# benchmarks still run end-to-end and produce non-NaN carbon totals.
SMOKE = os.environ.get("GREENCACHE_SMOKE", "") not in ("", "0")
GRIDS = ["FR", "FI", "ES", "CISO"]
# factories accept a load ``scale`` so multi-replica scenarios widen the
# working set proportionally to the scaled-up request rate
TASKS = {
    "conversation": dict(
        policy="lcs_chat",
        factory=lambda s, scale=1.0: ConversationWorkload(seed=s,
                                                          load_scale=scale)),
    "doc_a04": dict(
        policy="lcs_doc",
        factory=lambda s, scale=1.0: DocumentWorkload(seed=s, zipf_alpha=0.4,
                                                      load_scale=scale)),
    "doc_a07": dict(
        policy="lcs_doc",
        factory=lambda s, scale=1.0: DocumentWorkload(seed=s, zipf_alpha=0.7,
                                                      load_scale=scale)),
}
# profiled operating ranges (rates scaled to each platform's capacity)
RATE_GRID = {
    ("llama3-70b", "conversation"): [0.2, 0.6, 1.0, 1.3, 1.6],
    ("llama3-70b", "doc_a04"): [0.1, 0.25, 0.45, 0.65],
    ("llama3-70b", "doc_a07"): [0.1, 0.25, 0.45, 0.65],
    ("llama3-8b", "conversation"): [0.5, 1.5, 2.5, 3.5, 4.5],
    ("llama3-8b", "doc_a04"): [0.3, 0.8, 1.5, 2.2],
    ("llama3-8b", "doc_a07"): [0.3, 0.8, 1.5, 2.2],
}
SIZE_GRID = {"llama3-70b": [0, 1, 2, 4, 8, 12, 16],
             "llama3-8b": [0, 1, 2, 4, 6, 8]}
WARMUP = {"conversation": 12000, "doc_a04": 6000, "doc_a07": 6000}

if SMOKE:
    GRIDS = ["FR"]
    RATE_GRID = {k: v[:2] for k, v in RATE_GRID.items()}
    SIZE_GRID = {k: [v[0], v[3]] for k, v in SIZE_GRID.items()}
    WARMUP = {k: 400 for k in WARMUP}

CARBON = CarbonModel()


def clip_day(*traces, hours: int = 4):
    """Smoke mode truncates hourly day traces to a few hours; otherwise
    the traces pass through unchanged."""
    out = tuple(t[:hours] for t in traces) if SMOKE else tuple(traces)
    return out if len(out) > 1 else out[0]


def cap_requests(n: int, cap: int = 150) -> int:
    """Smoke mode caps per-window request counts (simulation volume)."""
    return min(int(n), cap) if SMOKE else int(n)


def profiler_kwargs() -> Dict:
    """Measurement-window overrides for benchmarks that call
    ``run_profiler`` directly with their own grids."""
    return dict(meas_seconds=90.0, ramp_seconds=20.0) if SMOKE else {}


def task_name_for_slo(task: str) -> str:
    return task if task == "conversation" else "document"


@functools.lru_cache(maxsize=None)
def get_profile(model_name: str, task: str) -> Profile:
    m = SERVING_MODELS[model_name]
    t = TASKS[task]
    return run_profiler(
        m, task_name_for_slo(task), t["factory"], CARBON,
        rates=RATE_GRID[(model_name, task)], sizes_tb=SIZE_GRID[model_name],
        warmup_prompts=WARMUP[task], policy=t["policy"],
        **profiler_kwargs())


def measure_cell(model_name: str, task: str, *, cache_tb: float = None,
                 rate: float, ci: float, policy: str | None = None,
                 warm: int | None = None, n_seconds: float = 400.0,
                 seed: int = 1, hw=None, n_replicas: int = 1,
                 router: str | None = None, partitioned: bool = False,
                 types=None,
                 balance_eps: float | None = DEFAULT_BALANCE_EPS,
                 plan=None):
    """One steady-state measurement (used by Figs 3, 5-8, 15, 19, 20).
    ``plan`` (a ``ResourcePlan`` or plan string, carrying a concrete
    cache size) is the preferred cluster spelling — a disaggregated plan
    measures a prefill/decode pool pair. The remaining kwargs are the
    pre-plan spelling: ``n_replicas``/``router``/``partitioned`` select a
    multi-replica cluster (``cache_tb`` stays the cluster-total
    allocation; ``rate`` the cluster arrival rate), ``types`` a
    heterogeneous fleet, ``balance_eps`` the cache_affinity router's
    bounded-load spill (None disables it)."""
    from repro.core.carbon import fleet_capacity
    from repro.workloads import sample_many
    m = SERVING_MODELS[model_name]
    carbon = CarbonModel(hw=hw) if hw is not None else CARBON
    t = TASKS[task]
    policy = policy or t["policy"]
    if isinstance(plan, str):
        plan = ResourcePlan.parse(plan)
    if plan is not None:
        if (cache_tb, n_replicas, router, partitioned, types,
                balance_eps) != (None, 1, None, False, None,
                                 DEFAULT_BALANCE_EPS):
            raise ValueError("pass plan= or the legacy cluster kwargs, "
                             "not both")
        cache_tb = plan.cache_tb
        # the workload widens with the arrival-carrying (prefill)
        # capacity — a disaggregated plan's decode pool adds token
        # throughput, not request admission (same rule as serve.py)
        scale = plan.prefill.capacity
        eng = make_cluster(m, carbon, policy=POLICIES[policy], plan=plan)
    else:
        scale = fleet_capacity(types) if types is not None \
            else max(float(n_replicas), 1.0)
        eng = make_cluster(m, carbon, cache_tb=cache_tb,
                           policy=POLICIES[policy], n_replicas=n_replicas,
                           router=router, partitioned=partitioned,
                           types=types, balance_eps=balance_eps)
    wl = t["factory"](seed, scale=max(scale, 1.0))
    warm = WARMUP[task] if warm is None else warm
    if SMOKE:
        warm = min(warm, 400)
        n_seconds = min(n_seconds, 60.0)
    n_meas = max(int(rate * n_seconds), 150)
    arr = make_poisson_arrivals(np.full(96, rate), seed=seed + 1,
                                max_requests=warm + n_meas)
    reqs = sample_many(wl, arr)
    eng.warm(reqs[:warm])
    for store in eng.stores:
        store.stats.lookups = store.stats.hits = 0
        store.stats.hit_tokens = store.stats.lookup_tokens = 0
    res = eng.run(reqs[warm:warm + n_meas], ci_fn=lambda _: ci,
                  cache_tb=cache_tb)
    return res


def save_result(name: str, payload: Dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.elapsed = time.time() - self.t0

    @property
    def us_per_call(self) -> float:
        return self.elapsed * 1e6
